//! Integer-domain GEMM kernels: compute directly on packed quantization
//! levels instead of dequantizing to f32 first.
//!
//! GETA's quantizer puts every fake-quantized value on an integer grid:
//! `fake_quant(x) = d * l` with `l = quantize_level(x) ∈ ℤ` (see
//! `quant::quantize_level`). A learned bit width `b ≤ 8` bounds the levels
//! by `|l| ≤ 2^(b-1) - 1 ≤ 127`, so both a quantized weight tensor and a
//! quantized activation tensor are **exactly** representable as `i8`. The
//! kernels here exploit that:
//!
//! * [`matmul_i8_into`] — `i8 × i8 → i32`: the contraction
//!   `Σ_k la[i,k] · lw[k,j]` is a sum of integers bounded by
//!   `k · 127 · 127 < 2^31` (callers gate on [`i8_gemm_fits_i32`]), so the
//!   i32 accumulation is **exact** — not "accurate", exact. There is no
//!   rounding anywhere in the contraction; the only floating-point
//!   rounding of the whole integer path lives in the scale epilogue.
//! * [`matmul_i8_scaled_into`] — the deployment form: the same exact i32
//!   tiles, flushed through an f64 epilogue
//!   `out[i,j] = f32(acc · (alpha · scale[j]) + bias[j])` that folds the
//!   weight dequantization step `d_w` (per output channel, `scale`) and
//!   the activation step `d_a` (`alpha`) into one multiply.
//! * [`matmul_f32i8_scaled_into`] — the mixed form for weight-only
//!   quantization (resnet, the transformers): f32 activations × resident
//!   i8 weight levels, f64 accumulation in the exact per-row order of the
//!   f32 kernels, `d_w` folded into the epilogue. The weight operand stays
//!   i8 in memory (4× less panel traffic than dequantized f32) and is
//!   widened in-register.
//! * [`im2col_i8_into`] / [`levels_from_grid`] — conv support and the
//!   runtime activation-quantization step: recover the integer level of a
//!   value already on the `d`-grid.
//!
//! Layout and partitioning mirror `ops.rs`: row-major flat buffers,
//! `TILE_I × TILE_K` cache blocking, output rows split across
//! `kernel_threads` workers. Determinism: the i8×i8 kernels accumulate in
//! i32, which is associative — results are bitwise identical for every
//! thread count *by construction*; the mixed kernel keeps the f32 kernels'
//! fixed per-row accumulation order (a function of `(k, TILE_K)` only) for
//! the same guarantee.

use super::tile::{kernel_threads, TILE_I, TILE_K};

/// One weight tensor held as resident integer levels — the deployment
/// engine's weight-stationary layout. `levels` is `[k, n]` row-major,
/// exactly the flattening the f32 GEMM consumes (linear `[din, dout]`;
/// conv HWIO flattened to `[k²·cin, cout]`), so the integer kernels walk
/// the same panels the f32 kernels would.
#[derive(Debug, Clone)]
pub struct IntWeight {
    /// Quantization levels, `[k, n]` row-major.
    pub levels: Vec<i8>,
    /// Contraction length (weight rows).
    pub k: usize,
    /// Output channels (weight cols).
    pub n: usize,
    /// Per-output-channel dequantization scale (the site's step `d_w`;
    /// uniform today, per-channel by layout so finer-grained schemes slot
    /// in without a kernel change).
    pub scale: Vec<f32>,
    /// `max |level|`, for the i32 overflow gate.
    pub max_abs: i32,
}

impl IntWeight {
    /// Build from unpacked container levels, or `None` when any level
    /// falls outside i8 (a site trained past 8 bits — the caller falls
    /// back to the dequantized-f32 path for that tensor).
    pub fn from_levels(levels: &[i32], n: usize, d: f32) -> Option<IntWeight> {
        if n == 0 || levels.len() % n != 0 {
            return None;
        }
        let mut max_abs = 0i32;
        for &l in levels {
            if l < i8::MIN as i32 || l > i8::MAX as i32 {
                return None;
            }
            max_abs = max_abs.max(l.abs());
        }
        Some(IntWeight {
            levels: levels.iter().map(|&l| l as i8).collect(),
            k: levels.len() / n,
            n,
            scale: vec![d; n],
            max_abs,
        })
    }
}

/// Can `Σ_k a·w` with `|a| ≤ max_a`, `|w| ≤ max_w` overflow i32? The
/// worst-case magnitude is `k · max_a · max_w`; the i8×i8 path requires it
/// to fit so the accumulation stays exact.
pub fn i8_gemm_fits_i32(k: usize, max_a: i32, max_w: i32) -> bool {
    (k as i64)
        .saturating_mul(max_a.max(0) as i64)
        .saturating_mul(max_w.max(0) as i64)
        <= i32::MAX as i64
}

/// Recover the integer levels of values already on the `d`-grid (the
/// output of `fake_quant`, for which `x = fl(d·l)`): `round(x / d)`,
/// clamped to i8. For `|l| ≤ 127` the f32 division error is far below
/// 1/2, so the recovery is exact — this is the runtime
/// activation-quantization step feeding the i8×i8 kernels.
pub fn levels_from_grid(x: &[f32], d: f32, out: &mut [i8]) {
    assert_eq!(x.len(), out.len());
    assert!(d > 0.0, "degenerate quant step {d}");
    let inv = 1.0 / d;
    for (o, &v) in out.iter_mut().zip(x) {
        *o = (v * inv).round().clamp(i8::MIN as f32, i8::MAX as f32) as i8;
    }
}

// ------------------------------------------------------------ i8 × i8 GEMM

/// `a[m,k] @ b[k,n]` on levels, exact i32 accumulation — tiled + threaded.
pub fn matmul_i8(a: &[i8], b: &[i8], m: usize, k: usize, n: usize) -> Vec<i32> {
    let mut out = vec![0i32; m * n];
    matmul_i8_into(&mut out, a, b, m, k, n);
    out
}

/// [`matmul_i8`] writing into a caller-provided buffer. The caller
/// guarantees no i32 overflow ([`i8_gemm_fits_i32`]); debug builds check a
/// conservative bound.
pub fn matmul_i8_into(out: &mut [i32], a: &[i8], b: &[i8], m: usize, k: usize, n: usize) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    assert_eq!(out.len(), m * n);
    debug_assert!(i8_gemm_fits_i32(k, 128, 128) || {
        let ma = a.iter().map(|&v| (v as i32).abs()).max().unwrap_or(0);
        let mb = b.iter().map(|&v| (v as i32).abs()).max().unwrap_or(0);
        i8_gemm_fits_i32(k, ma, mb)
    });
    if out.is_empty() {
        return;
    }
    if k == 0 {
        out.fill(0);
        return;
    }
    let nt = kernel_threads(m * k * n, m);
    if nt <= 1 {
        matmul_i8_rows(out, a, b, 0, k, n);
        return;
    }
    let chunk = m.div_ceil(nt);
    std::thread::scope(|sc| {
        for (ti, oc) in out.chunks_mut(chunk * n).enumerate() {
            sc.spawn(move || matmul_i8_rows(oc, a, b, ti * chunk, k, n));
        }
    });
}

/// Accumulate rows `ib..ib+ilen` (absolute `i0+ib..`) of `a @ b` into the
/// i32 tile `acc` (`ilen × n`, pre-zeroed). Shared by the raw and the
/// scaled-epilogue drivers so the two cannot diverge. With the `simd`
/// feature an arch-specific body runs first (`simd.rs`) — i32 sums are
/// exact under the overflow gate, so any lane order is bitwise equal.
#[inline]
fn acc_tile_i8(
    acc: &mut [i32],
    a: &[i8],
    b: &[i8],
    row0: usize,
    ilen: usize,
    k: usize,
    n: usize,
) {
    #[cfg(feature = "simd")]
    if super::simd::acc_tile_i8(acc, a, b, row0, ilen, k, n) {
        return;
    }
    for kb in (0..k).step_by(TILE_K) {
        let klen = TILE_K.min(k - kb);
        for ii in 0..ilen {
            let arow = &a[(row0 + ii) * k + kb..][..klen];
            let accrow = &mut acc[ii * n..(ii + 1) * n];
            let mut kk = 0;
            while kk + 4 <= klen {
                let a0 = arow[kk] as i32;
                let a1 = arow[kk + 1] as i32;
                let a2 = arow[kk + 2] as i32;
                let a3 = arow[kk + 3] as i32;
                if a0 != 0 || a1 != 0 || a2 != 0 || a3 != 0 {
                    let b0 = &b[(kb + kk) * n..][..n];
                    let b1 = &b[(kb + kk + 1) * n..][..n];
                    let b2 = &b[(kb + kk + 2) * n..][..n];
                    let b3 = &b[(kb + kk + 3) * n..][..n];
                    for j in 0..n {
                        accrow[j] += a0 * b0[j] as i32
                            + a1 * b1[j] as i32
                            + a2 * b2[j] as i32
                            + a3 * b3[j] as i32;
                    }
                }
                kk += 4;
            }
            while kk < klen {
                let av = arow[kk] as i32;
                if av != 0 {
                    let brow = &b[(kb + kk) * n..][..n];
                    for j in 0..n {
                        accrow[j] += av * brow[j] as i32;
                    }
                }
                kk += 1;
            }
        }
    }
}

fn matmul_i8_rows(out: &mut [i32], a: &[i8], b: &[i8], i0: usize, k: usize, n: usize) {
    let rows = out.len() / n;
    let mut acc = vec![0i32; TILE_I.min(rows.max(1)) * n];
    for ib in (0..rows).step_by(TILE_I) {
        let ilen = TILE_I.min(rows - ib);
        let acc = &mut acc[..ilen * n];
        acc.fill(0);
        acc_tile_i8(acc, a, b, i0 + ib, ilen, k, n);
        out[ib * n..(ib + ilen) * n].copy_from_slice(acc);
    }
}

/// The deployment i8×i8 GEMM: exact i32 tiles flushed through the f64
/// scale epilogue `out[i,j] = f32(acc[i,j] · (alpha · scale[j]) + bias[j])`
/// — `scale` is the per-output-channel weight step `d_w`, `alpha` the
/// activation step `d_a` (pass 1.0 for raw-level outputs). The epilogue is
/// the **only** floating-point rounding of the integer path.
#[allow(clippy::too_many_arguments)]
pub fn matmul_i8_scaled_into(
    out: &mut [f32],
    a: &[i8],
    b: &[i8],
    m: usize,
    k: usize,
    n: usize,
    scale: &[f32],
    alpha: f32,
    bias: Option<&[f32]>,
) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    assert_eq!(out.len(), m * n);
    assert_eq!(scale.len(), n);
    if let Some(bias) = bias {
        assert_eq!(bias.len(), n);
    }
    if out.is_empty() {
        return;
    }
    // fold alpha·scale once per call (shared by every worker); f64 so the
    // fold itself is exact to f32 inputs and the epilogue rounds exactly
    // once per element
    let comb: Vec<f64> = scale.iter().map(|&s| alpha as f64 * s as f64).collect();
    let comb = comb.as_slice();
    let nt = kernel_threads(m * k * n, m);
    if nt <= 1 {
        matmul_i8_scaled_rows(out, a, b, 0, k, n, comb, bias);
        return;
    }
    let chunk = m.div_ceil(nt);
    std::thread::scope(|sc| {
        for (ti, oc) in out.chunks_mut(chunk * n).enumerate() {
            sc.spawn(move || matmul_i8_scaled_rows(oc, a, b, ti * chunk, k, n, comb, bias));
        }
    });
}

#[allow(clippy::too_many_arguments)]
fn matmul_i8_scaled_rows(
    out: &mut [f32],
    a: &[i8],
    b: &[i8],
    i0: usize,
    k: usize,
    n: usize,
    comb: &[f64],
    bias: Option<&[f32]>,
) {
    let rows = out.len() / n;
    let mut acc = vec![0i32; TILE_I.min(rows.max(1)) * n];
    for ib in (0..rows).step_by(TILE_I) {
        let ilen = TILE_I.min(rows - ib);
        let acc = &mut acc[..ilen * n];
        acc.fill(0);
        acc_tile_i8(acc, a, b, i0 + ib, ilen, k, n);
        for ii in 0..ilen {
            let orow = &mut out[(ib + ii) * n..(ib + ii + 1) * n];
            match bias {
                Some(bias) => {
                    for j in 0..n {
                        orow[j] = (acc[ii * n + j] as f64 * comb[j] + bias[j] as f64) as f32;
                    }
                }
                None => {
                    for j in 0..n {
                        orow[j] = (acc[ii * n + j] as f64 * comb[j]) as f32;
                    }
                }
            }
        }
    }
}

// ------------------------------------------------------ f32 × i8 GEMM (mixed)

/// Mixed GEMM for weight-only quantization: f32 activations against
/// resident i8 weight levels, f64 accumulation, per-output-channel scale
/// (+ optional bias) epilogue. The accumulation order per row is the same
/// function of `(k, TILE_K)` as the f32 kernels', so results are bitwise
/// thread-count-invariant.
#[allow(clippy::too_many_arguments)]
pub fn matmul_f32i8_scaled_into(
    out: &mut [f32],
    a: &[f32],
    b: &[i8],
    m: usize,
    k: usize,
    n: usize,
    scale: &[f32],
    bias: Option<&[f32]>,
) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    assert_eq!(out.len(), m * n);
    assert_eq!(scale.len(), n);
    if let Some(bias) = bias {
        assert_eq!(bias.len(), n);
    }
    if out.is_empty() {
        return;
    }
    let nt = kernel_threads(m * k * n, m);
    if nt <= 1 {
        matmul_f32i8_rows(out, a, b, 0, k, n, scale, bias);
        return;
    }
    let chunk = m.div_ceil(nt);
    std::thread::scope(|sc| {
        for (ti, oc) in out.chunks_mut(chunk * n).enumerate() {
            sc.spawn(move || matmul_f32i8_rows(oc, a, b, ti * chunk, k, n, scale, bias));
        }
    });
}

#[allow(clippy::too_many_arguments)]
fn matmul_f32i8_rows(
    out: &mut [f32],
    a: &[f32],
    b: &[i8],
    i0: usize,
    k: usize,
    n: usize,
    scale: &[f32],
    bias: Option<&[f32]>,
) {
    let rows = out.len() / n;
    let mut acc = vec![0.0f64; TILE_I.min(rows.max(1)) * n];
    for ib in (0..rows).step_by(TILE_I) {
        let ilen = TILE_I.min(rows - ib);
        let acc = &mut acc[..ilen * n];
        acc.fill(0.0);
        acc_tile_f32i8(acc, a, b, i0 + ib, ilen, k, n);
        for ii in 0..ilen {
            let orow = &mut out[(ib + ii) * n..(ib + ii + 1) * n];
            match bias {
                Some(bias) => {
                    for j in 0..n {
                        orow[j] = (acc[ii * n + j] * scale[j] as f64 + bias[j] as f64) as f32;
                    }
                }
                None => {
                    for j in 0..n {
                        orow[j] = (acc[ii * n + j] * scale[j] as f64) as f32;
                    }
                }
            }
        }
    }
}

/// Accumulate rows `row0..row0+ilen` of `a @ b` (f32 × i8 levels) into
/// the f64 tile `acc` (`ilen × n`, pre-zeroed) — the same per-column
/// accumulation order as the f32 kernels. The `simd` dispatch body
/// replays that order exactly (see `simd.rs`).
fn acc_tile_f32i8(
    acc: &mut [f64],
    a: &[f32],
    b: &[i8],
    row0: usize,
    ilen: usize,
    k: usize,
    n: usize,
) {
    #[cfg(feature = "simd")]
    if super::simd::acc_tile_f32i8(acc, a, b, row0, ilen, k, n) {
        return;
    }
    for kb in (0..k).step_by(TILE_K) {
        let klen = TILE_K.min(k - kb);
        for ii in 0..ilen {
            let arow = &a[(row0 + ii) * k + kb..][..klen];
            let accrow = &mut acc[ii * n..(ii + 1) * n];
            let mut kk = 0;
            while kk + 4 <= klen {
                let a0 = arow[kk] as f64;
                let a1 = arow[kk + 1] as f64;
                let a2 = arow[kk + 2] as f64;
                let a3 = arow[kk + 3] as f64;
                if a0 != 0.0 || a1 != 0.0 || a2 != 0.0 || a3 != 0.0 {
                    let b0 = &b[(kb + kk) * n..][..n];
                    let b1 = &b[(kb + kk + 1) * n..][..n];
                    let b2 = &b[(kb + kk + 2) * n..][..n];
                    let b3 = &b[(kb + kk + 3) * n..][..n];
                    for j in 0..n {
                        accrow[j] += a0 * b0[j] as f64
                            + a1 * b1[j] as f64
                            + a2 * b2[j] as f64
                            + a3 * b3[j] as f64;
                    }
                }
                kk += 4;
            }
            while kk < klen {
                let av = arow[kk] as f64;
                if av != 0.0 {
                    let brow = &b[(kb + kk) * n..][..n];
                    for j in 0..n {
                        accrow[j] += av * brow[j] as f64;
                    }
                }
                kk += 1;
            }
        }
    }
}

// ------------------------------------------------------------- i8 im2col

/// [`super::im2col_into`] on level tensors: `x[b,h,w,c] -> cols[b·ho·wo,
/// k·k·c]` with the same column index convention. Out-of-image taps stay
/// level 0 (which dequantizes to exactly 0.0 — padding is exact).
#[allow(clippy::too_many_arguments)]
pub fn im2col_i8_into(
    cols: &mut [i8],
    x: &[i8],
    bsz: usize,
    h: usize,
    w: usize,
    c: usize,
    k: usize,
    stride: usize,
    pad: usize,
    ho: usize,
    wo: usize,
) {
    assert_eq!(x.len(), bsz * h * w * c);
    assert_eq!(cols.len(), bsz * ho * wo * k * k * c);
    cols.fill(0);
    let rowlen = k * k * c;
    for bi in 0..bsz {
        for oh in 0..ho {
            for kh in 0..k {
                let ih = (oh * stride + kh) as isize - pad as isize;
                if ih < 0 || ih >= h as isize {
                    continue;
                }
                for ow in 0..wo {
                    let r = (bi * ho + oh) * wo + ow;
                    for kw in 0..k {
                        let iw = (ow * stride + kw) as isize - pad as isize;
                        if iw < 0 || iw >= w as isize {
                            continue;
                        }
                        let src = ((bi * h + ih as usize) * w + iw as usize) * c;
                        let dst = r * rowlen + (kh * k + kw) * c;
                        cols[dst..dst + c].copy_from_slice(&x[src..src + c]);
                    }
                }
            }
        }
    }
}

/// Naive reference `a[m,k] @ b[k,n]` on levels: the triple loop the tiled
/// kernel's property tests compare against — the comparison is **exact
/// equality**, not a tolerance, because both sides accumulate in i32.
pub fn matmul_i8_naive(a: &[i8], b: &[i8], m: usize, k: usize, n: usize) -> Vec<i32> {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    let mut out = vec![0i32; m * n];
    for i in 0..m {
        for kk in 0..k {
            let av = a[i * k + kk] as i32;
            if av == 0 {
                continue;
            }
            let brow = &b[kk * n..(kk + 1) * n];
            let orow = &mut out[i * n..(i + 1) * n];
            for j in 0..n {
                orow[j] += av * brow[j] as i32;
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{self, QParams};
    use crate::tensor::tile::THREAD_TEST_LOCK;
    use crate::tensor::{self, ops};
    use crate::util::prop;

    fn rand_levels(g: &mut prop::Gen, n: usize, bits: u8) -> Vec<i8> {
        let cap = (1i32 << (bits - 1)) - 1;
        (0..n)
            .map(|_| (g.f32_in(-(cap as f32), cap as f32)).round() as i8)
            .collect()
    }

    #[test]
    fn int_weight_from_levels_gates_i8_range() {
        let w = IntWeight::from_levels(&[-127, 0, 64, 127], 2, 0.25).unwrap();
        assert_eq!(w.k, 2);
        assert_eq!(w.n, 2);
        assert_eq!(w.max_abs, 127);
        assert_eq!(w.scale, vec![0.25, 0.25]);
        assert_eq!(w.levels, vec![-127, 0, 64, 127]);
        // 9-bit levels must refuse (the f32 fallback handles them)
        assert!(IntWeight::from_levels(&[200, 0], 1, 0.1).is_none());
        assert!(IntWeight::from_levels(&[-300, 0], 1, 0.1).is_none());
        // ragged shape refuses
        assert!(IntWeight::from_levels(&[1, 2, 3], 2, 0.1).is_none());
    }

    #[test]
    fn overflow_gate() {
        assert!(i8_gemm_fits_i32(1 << 16, 127, 127));
        assert!(!i8_gemm_fits_i32(1 << 18, 127, 127));
        assert!(i8_gemm_fits_i32(usize::MAX, 0, 127)); // zero operand never overflows
    }

    #[test]
    fn levels_from_grid_inverts_fake_quant_exactly() {
        // fake_quant puts x on the d-grid; levels_from_grid must recover
        // the exact quantize_level integer — including at t != 1, where
        // re-quantizing the output would NOT be a fixed point
        for &(d, t, qm) in &[(0.05f32, 1.0f32, 1.0f32), (0.031, 1.15, 1.3), (0.11, 0.85, 0.7)] {
            let qp = QParams { d, t, qm };
            let xs: Vec<f32> = (-40..40).map(|i| i as f32 * 0.07).collect();
            let grid: Vec<f32> = xs.iter().map(|&x| quant::fake_quant(x, &qp)).collect();
            let mut got = vec![0i8; xs.len()];
            levels_from_grid(&grid, d, &mut got);
            for (i, &x) in xs.iter().enumerate() {
                let want = quant::quantize_level(x, &qp);
                assert_eq!(got[i] as i32, want, "x={x} d={d} t={t} qm={qm}");
            }
        }
    }

    #[test]
    fn matmul_i8_hand_values() {
        // [2,3] @ [3,2] on small levels
        let a: Vec<i8> = vec![1, -2, 3, 0, 5, -6];
        let b: Vec<i8> = vec![7, 8, 9, 10, 11, 12];
        assert_eq!(matmul_i8(&a, &b, 2, 3, 2), vec![22, 24, -21, -22]);
        // empty contraction is all zeros
        assert_eq!(matmul_i8(&[], &[], 2, 0, 2), vec![0; 4]);
    }

    #[test]
    fn prop_tiled_i8_matches_naive_exactly_across_threads_and_bits() {
        // exact i32 equality (no tolerance): bits 2..=8, threads 1/2/4,
        // shapes crossing the tile borders and the spawn threshold
        let _guard = THREAD_TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        let prev = tensor::configured_threads();
        for &threads in &[1usize, 2, 4] {
            tensor::set_threads(threads);
            prop::check(
                10,
                |g| {
                    let bits = 2 + g.rng.below(7) as u8; // 2..=8
                    let big = g.f32_in(0.0, 1.0) < 0.4;
                    let m = if big { 64 + g.size(400) } else { g.size(40) };
                    let k = g.size(if big { 300 } else { 24 });
                    let n = g.size(if big { 48 } else { 24 });
                    let a = rand_levels(g, m * k, bits);
                    let b = rand_levels(g, k * n, bits);
                    (bits, m, k, n, a, b)
                },
                |(bits, m, k, n, a, b)| {
                    let (m, k, n) = (*m, *k, *n);
                    let got = matmul_i8(a, b, m, k, n);
                    let want = matmul_i8_naive(a, b, m, k, n);
                    if got == want {
                        Ok(())
                    } else {
                        Err(format!("bits={bits} threads={threads} m={m} k={k} n={n}: mismatch"))
                    }
                },
            );
        }
        tensor::set_threads(prev);
    }

    #[test]
    fn prop_scaled_i8_matches_f32_reference_on_dequantized_operands() {
        // the parity argument in miniature: i8×i8 + scale epilogue vs the
        // f32 kernel on dequantized operands, 1e-4 relative — across bits
        prop::check(
            30,
            |g| {
                let bits = 2 + g.rng.below(7) as u8;
                let m = g.size(24);
                let k = g.size(40);
                let n = g.size(16);
                let a = rand_levels(g, m * k, bits);
                let b = rand_levels(g, k * n, bits);
                // realistic step sizes (d·2^(b-1) ≈ q_m ≈ 1): keeps the
                // f32 reference's own per-term rounding well below the
                // 1e-4 comparison bar even under heavy cancellation
                let da = g.f32_in(1e-3, 5e-3);
                let dw = g.f32_in(1e-3, 5e-3);
                let bias = g.vec_normal(n, 0.5);
                (m, k, n, a, b, da, dw, bias)
            },
            |(m, k, n, a, b, da, dw, bias)| {
                let (m, k, n) = (*m, *k, *n);
                let af: Vec<f32> = a.iter().map(|&l| l as f32 * da).collect();
                let bf: Vec<f32> = b.iter().map(|&l| l as f32 * dw).collect();
                let mut want = ops::matmul(&af, &bf, m, k, n);
                for r in 0..m {
                    ops::axpy(1.0, bias, &mut want[r * n..(r + 1) * n]);
                }
                let scale = vec![*dw; n];
                let mut got = vec![0.0f32; m * n];
                matmul_i8_scaled_into(&mut got, a, b, m, k, n, &scale, *da, Some(bias));
                for i in 0..want.len() {
                    if (got[i] - want[i]).abs() > 1e-4 * (1.0 + want[i].abs()) {
                        return Err(format!(
                            "[{i}] int {} vs f32 {} (m={m} k={k} n={n})",
                            got[i], want[i]
                        ));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_mixed_f32i8_matches_f32_reference() {
        // mixed kernel vs the f32 kernel on the dequantized weight
        prop::check(
            30,
            |g| {
                let bits = 2 + g.rng.below(7) as u8;
                let m = g.size(24);
                let k = g.size(40);
                let n = g.size(16);
                let mut a = g.vec_normal(m * k, 1.0);
                for v in a.iter_mut() {
                    if *v < 0.0 {
                        *v = 0.0; // relu-sparse: exercise the zero-skip
                    }
                }
                let b = rand_levels(g, k * n, bits);
                let dw = g.f32_in(1e-3, 5e-3); // see the scaled test above
                let bias = g.vec_normal(n, 0.5);
                (m, k, n, a, b, dw, bias)
            },
            |(m, k, n, a, b, dw, bias)| {
                let (m, k, n) = (*m, *k, *n);
                let bf: Vec<f32> = b.iter().map(|&l| l as f32 * dw).collect();
                let mut want = ops::matmul(a, &bf, m, k, n);
                for r in 0..m {
                    ops::axpy(1.0, bias, &mut want[r * n..(r + 1) * n]);
                }
                let scale = vec![*dw; n];
                let mut got = vec![0.0f32; m * n];
                matmul_f32i8_scaled_into(&mut got, a, b, m, k, n, &scale, Some(bias));
                for i in 0..want.len() {
                    if (got[i] - want[i]).abs() > 1e-4 * (1.0 + want[i].abs()) {
                        return Err(format!(
                            "[{i}] mixed {} vs f32 {} (m={m} k={k} n={n})",
                            got[i], want[i]
                        ));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn int_kernels_are_bitwise_thread_count_invariant() {
        let _guard = THREAD_TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        let prev = tensor::configured_threads();
        let mut rng = crate::util::rng::Rng::new(29);
        let (m, k, n) = (300, 70, 40);
        let a: Vec<i8> = (0..m * k).map(|_| (rng.below(255) as i32 - 127) as i8).collect();
        let b: Vec<i8> = (0..k * n).map(|_| (rng.below(255) as i32 - 127) as i8).collect();
        let mut af = vec![0.0f32; m * k];
        rng.fill_normal(&mut af, 1.0);
        let scale = vec![0.013f32; n];
        let mut bias = vec![0.0f32; n];
        rng.fill_normal(&mut bias, 0.3);
        let run = |_: usize| {
            let raw = matmul_i8(&a, &b, m, k, n);
            let mut scaled = vec![0.0f32; m * n];
            matmul_i8_scaled_into(&mut scaled, &a, &b, m, k, n, &scale, 0.07, Some(&bias));
            let mut mixed = vec![0.0f32; m * n];
            matmul_f32i8_scaled_into(&mut mixed, &af, &b, m, k, n, &scale, Some(&bias));
            (raw, scaled, mixed)
        };
        tensor::set_threads(1);
        let base = run(1);
        for threads in [2usize, 3, 4, 8] {
            tensor::set_threads(threads);
            let got = run(threads);
            assert_eq!(base.0, got.0, "matmul_i8 @ {threads} threads");
            assert_eq!(base.1, got.1, "matmul_i8_scaled @ {threads} threads");
            assert_eq!(base.2, got.2, "matmul_f32i8 @ {threads} threads");
        }
        tensor::set_threads(prev);
    }

    #[test]
    fn im2col_i8_matches_f32_im2col_on_levels() {
        let mut rng = crate::util::rng::Rng::new(31);
        let (bsz, h, w, c, k, stride) = (2, 5, 4, 3, 3, 1);
        let (ho, pad) = ops::conv_out_dim(h, k, stride, true);
        let (wo, _) = ops::conv_out_dim(w, k, stride, true);
        let x: Vec<i8> = (0..bsz * h * w * c)
            .map(|_| (rng.below(255) as i32 - 127) as i8)
            .collect();
        let xf: Vec<f32> = x.iter().map(|&v| v as f32).collect();
        let want = ops::im2col(&xf, bsz, h, w, c, k, stride, pad, ho, wo);
        let mut got = vec![7i8; want.len()]; // dirty buffer: fill(0) must reset
        im2col_i8_into(&mut got, &x, bsz, h, w, c, k, stride, pad, ho, wo);
        for (i, (&g, &wv)) in got.iter().zip(&want).enumerate() {
            assert_eq!(g as f32, wv, "col[{i}]");
        }
    }
}
