//! Dense f32 tensors and the named parameter store.
//!
//! All QASSO state (weights, momenta, quantized copies) lives here as flat
//! f32 buffers with shapes; the numeric helpers (norms, dot, cosine, axpy)
//! are the Layer-3 hot-path primitives profiled in EXPERIMENTS.md §Perf.

pub mod iops;
pub mod ops;
#[cfg(feature = "simd")]
pub(crate) mod simd;
pub mod tile;
pub mod u4;

pub use iops::*;
pub use ops::*;
pub use tile::{configured_threads, serial_scope, set_threads};
pub use u4::*;

/// True when the `simd` feature is compiled in **and** the running CPU
/// supports the vector paths the kernels dispatch to (AVX2 on x86_64,
/// NEON on aarch64). Used by the tracer to tag per-op spans so a trace
/// records which kernel tier actually ran, not just which was compiled.
pub fn simd_active() -> bool {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    {
        return std::arch::is_x86_feature_detected!("avx2");
    }
    #[cfg(all(feature = "simd", target_arch = "aarch64"))]
    {
        return true;
    }
    #[allow(unreachable_code)]
    false
}

#[derive(Debug, Clone)]
pub struct Tensor {
    pub name: String,
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn zeros(name: &str, shape: &[usize]) -> Tensor {
        let n: usize = shape.iter().product();
        Tensor {
            name: name.to_string(),
            shape: shape.to_vec(),
            data: vec![0.0; n],
        }
    }

    pub fn from_vec(name: &str, shape: &[usize], data: Vec<f32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "{name}: shape/data mismatch");
        Tensor {
            name: name.to_string(),
            shape: shape.to_vec(),
            data,
        }
    }

    /// Shape-carrying placeholder with **no data** — for tensors whose
    /// real payload lives elsewhere (the int8 deploy engine keeps weight
    /// levels in `IntWeight`s and parks only the shape here for slice
    /// propagation). `numel()` still reports the shape product; reading
    /// `data` yields an empty slice, never stale values.
    pub fn shape_only(name: &str, shape: &[usize]) -> Tensor {
        Tensor {
            name: name.to_string(),
            shape: shape.to_vec(),
            data: Vec::new(),
        }
    }

    /// Element count **by shape** (equal to `data.len()` for every tensor
    /// except [`shape_only`](Self::shape_only) placeholders).
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    /// Number of "output structures" along the prunable axis.
    /// conv HWIO: axis 3 (cout); linear [din, dout]: axis 1; 1-D: axis 0.
    pub fn out_dim(&self) -> usize {
        *self.shape.last().unwrap_or(&1)
    }

    /// Stride between consecutive elements of the same output index.
    /// With the prunable axis last (HWIO cout / linear dout), elements of
    /// output j are data[j], data[j + D], data[j + 2D]... where D = out_dim.
    pub fn out_stride(&self) -> usize {
        self.out_dim()
    }

    /// Iterate (and mutate) the slice of weights feeding output index `j`.
    pub fn for_output_mut(&mut self, j: usize, mut f: impl FnMut(&mut f32)) {
        let d = self.out_dim();
        let mut i = j;
        while i < self.data.len() {
            f(&mut self.data[i]);
            i += d;
        }
    }

    pub fn for_output(&self, j: usize, mut f: impl FnMut(f32)) {
        let d = self.out_dim();
        let mut i = j;
        while i < self.data.len() {
            f(self.data[i]);
            i += d;
        }
    }
}

/// Ordered, name-indexed collection of tensors. Order matches the AOT
/// manifest so packing into PJRT literals is a zip.
#[derive(Debug, Clone, Default)]
pub struct ParamStore {
    pub tensors: Vec<Tensor>,
    index: std::collections::BTreeMap<String, usize>,
}

impl ParamStore {
    pub fn new() -> Self {
        Default::default()
    }

    pub fn push(&mut self, t: Tensor) {
        assert!(
            !self.index.contains_key(&t.name),
            "duplicate tensor {}",
            t.name
        );
        self.index.insert(t.name.clone(), self.tensors.len());
        self.tensors.push(t);
    }

    pub fn len(&self) -> usize {
        self.tensors.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tensors.is_empty()
    }

    pub fn get(&self, name: &str) -> Option<&Tensor> {
        self.index.get(name).map(|&i| &self.tensors[i])
    }

    pub fn get_mut(&mut self, name: &str) -> Option<&mut Tensor> {
        let i = *self.index.get(name)?;
        Some(&mut self.tensors[i])
    }

    pub fn idx(&self, name: &str) -> Option<usize> {
        self.index.get(name).copied()
    }

    pub fn total_params(&self) -> usize {
        self.tensors.iter().map(|t| t.numel()).sum()
    }

    /// Zero-initialized clone with the same names/shapes (momentum buffers).
    pub fn zeros_like(&self) -> ParamStore {
        let mut s = ParamStore::new();
        for t in &self.tensors {
            s.push(Tensor::zeros(&t.name, &t.shape));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn out_slice_iteration_linear() {
        // linear [din=3, dout=2]: data row-major [d0o0 d0o1 d1o0 d1o1 d2o0 d2o1]
        let t = Tensor::from_vec("w", &[3, 2], vec![1., 10., 2., 20., 3., 30.]);
        let mut got = vec![];
        t.for_output(1, |v| got.push(v));
        assert_eq!(got, vec![10., 20., 30.]);
    }

    #[test]
    fn out_slice_iteration_conv() {
        // conv HWIO [1,1,2,3]: cout=3
        let t = Tensor::from_vec("w", &[1, 1, 2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let mut got = vec![];
        t.for_output(2, |v| got.push(v));
        assert_eq!(got, vec![3., 6.]);
    }

    #[test]
    fn for_output_mut_zeroes_structure() {
        let mut t = Tensor::from_vec("w", &[2, 2], vec![1., 2., 3., 4.]);
        t.for_output_mut(0, |v| *v = 0.0);
        assert_eq!(t.data, vec![0., 2., 0., 4.]);
    }

    #[test]
    fn store_roundtrip() {
        let mut s = ParamStore::new();
        s.push(Tensor::zeros("a", &[2, 3]));
        s.push(Tensor::zeros("b", &[4]));
        assert_eq!(s.len(), 2);
        assert_eq!(s.get("b").unwrap().numel(), 4);
        assert_eq!(s.idx("a"), Some(0));
        assert_eq!(s.total_params(), 10);
        let z = s.zeros_like();
        assert_eq!(z.tensors[1].name, "b");
    }

    #[test]
    fn shape_only_reports_shape_numel_with_empty_data() {
        let t = Tensor::shape_only("w", &[3, 4]);
        assert_eq!(t.numel(), 12);
        assert!(t.data.is_empty());
        // dense tensors agree between shape-numel and data length
        let d = Tensor::zeros("z", &[2, 5]);
        assert_eq!(d.numel(), d.data.len());
    }

    #[test]
    #[should_panic(expected = "duplicate")]
    fn duplicate_names_rejected() {
        let mut s = ParamStore::new();
        s.push(Tensor::zeros("a", &[1]));
        s.push(Tensor::zeros("a", &[1]));
    }
}
