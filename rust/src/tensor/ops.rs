//! Flat-buffer numeric kernels for the QASSO hot path.
//!
//! These run once per optimizer step over every parameter, so they are
//! written as straight loops over slices (auto-vectorizable; no bounds
//! checks in the hot loops after the explicit `assert_eq!` length pins).

/// y += alpha * x
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    assert_eq!(x.len(), y.len());
    for i in 0..x.len() {
        y[i] += alpha * x[i];
    }
}

/// y = alpha * y + beta * x   (in-place scaled blend)
pub fn scale_add(alpha: f32, y: &mut [f32], beta: f32, x: &[f32]) {
    assert_eq!(x.len(), y.len());
    for i in 0..y.len() {
        y[i] = alpha * y[i] + beta * x[i];
    }
}

pub fn dot(x: &[f32], y: &[f32]) -> f64 {
    assert_eq!(x.len(), y.len());
    let mut s = 0.0f64;
    for i in 0..x.len() {
        s += x[i] as f64 * y[i] as f64;
    }
    s
}

pub fn norm2(x: &[f32]) -> f64 {
    dot(x, x).sqrt()
}

pub fn mean_abs(x: &[f32]) -> f64 {
    if x.is_empty() {
        return 0.0;
    }
    x.iter().map(|v| v.abs() as f64).sum::<f64>() / x.len() as f64
}

pub fn max_abs(x: &[f32]) -> f32 {
    x.iter().fold(0.0f32, |m, v| m.max(v.abs()))
}

/// cos of the angle between -a and -b (== angle between a and b).
/// Returns 0 when either vector is ~zero (the paper's rules then take the
/// "any positive value" branch, which is what 0 selects).
pub fn cosine(a: &[f32], b: &[f32]) -> f64 {
    let na = norm2(a);
    let nb = norm2(b);
    if na < 1e-12 || nb < 1e-12 {
        return 0.0;
    }
    (dot(a, b) / (na * nb)).clamp(-1.0, 1.0)
}

pub fn zero(x: &mut [f32]) {
    for v in x.iter_mut() {
        *v = 0.0;
    }
}

/// Strided view helpers for "structure" slices (one output channel of a
/// tensor whose prunable axis is last). Gathers into `out` (reused buffer).
pub fn gather_strided(data: &[f32], start: usize, stride: usize, out: &mut Vec<f32>) {
    out.clear();
    let mut i = start;
    while i < data.len() {
        out.push(data[i]);
        i += stride;
    }
}

pub fn scatter_strided(data: &mut [f32], start: usize, stride: usize, vals: &[f32]) {
    let mut i = start;
    let mut k = 0;
    while i < data.len() {
        data[i] = vals[k];
        i += stride;
        k += 1;
    }
    assert_eq!(k, vals.len());
}

// Thread plumbing and tile constants live in `tile.rs` — one shared
// tiling config for the f32, i8 and u4 kernel families (and the SIMD
// dispatch layer), re-exported through `tensor::` unchanged.
use super::tile::{kernel_threads, TILE_I, TILE_K};

// ------------------------------------------------------------ dense GEMM
//
// All three contractions accumulate in f64 per tile: layer widths stay
// small but im2col rows reach ~8k, where f32 accumulation visibly drifts
// (see `dot_accumulates_in_f64_on_large_inputs`). The tiled kernels block
// the k axis so a panel of `b` rows stays cache-hot across a block of
// output rows, unroll k four-wide to cut load/index traffic, and split
// output rows across worker threads. Per output element the f64
// accumulation is a strict k-ascending fold (the unroll issues four
// *sequential* adds, never a grouped 4-term sum), so the tiled kernels are
// bitwise identical to the `*_naive` triple loops — and, because pruned
// channels hold exact zeros and adding ±0.0 to the fold is an identity,
// bitwise identical to the same GEMM with zero rows/columns physically
// sliced out (the shrink-as-you-train invariant). The `*_naive` loops are
// the ground truth the property tests compare against and the baseline
// `BENCH_runtime.json` measures speedups over. With the `simd` feature
// the inner row workers first try an arch-specific vectorized body
// (`simd.rs`) that replays the exact same accumulation order, so results
// stay bitwise identical to these scalar tiles.

/// `a[m,k] @ b[k,n]` (row-major flat buffers) — tiled + threaded.
pub fn matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; m * n];
    matmul_into(&mut out, a, b, m, k, n);
    out
}

/// [`matmul`] writing into a caller-provided (arena) buffer.
pub fn matmul_into(out: &mut [f32], a: &[f32], b: &[f32], m: usize, k: usize, n: usize) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    assert_eq!(out.len(), m * n);
    if out.is_empty() {
        return;
    }
    if k == 0 {
        zero(out);
        return;
    }
    let nt = kernel_threads(m * k * n, m);
    if nt <= 1 {
        matmul_rows(out, a, b, 0, k, n);
        return;
    }
    let chunk = m.div_ceil(nt);
    std::thread::scope(|sc| {
        for (ti, oc) in out.chunks_mut(chunk * n).enumerate() {
            sc.spawn(move || matmul_rows(oc, a, b, ti * chunk, k, n));
        }
    });
}

/// Rows `i0..i0 + out.len()/n` of `a @ b`. Per-row accumulation order is a
/// function of (k, TILE_K) only — independent of `i0` and tile/thread
/// partitioning, which is what makes results thread-count-invariant.
fn matmul_rows(out: &mut [f32], a: &[f32], b: &[f32], i0: usize, k: usize, n: usize) {
    let rows = out.len() / n;
    let mut acc = vec![0.0f64; TILE_I.min(rows) * n];
    for ib in (0..rows).step_by(TILE_I) {
        let ilen = TILE_I.min(rows - ib);
        let acc = &mut acc[..ilen * n];
        acc.fill(0.0);
        acc_tile_f32(acc, a, b, i0 + ib, ilen, k, n);
        for ii in 0..ilen {
            let orow = &mut out[(ib + ii) * n..(ib + ii + 1) * n];
            for j in 0..n {
                orow[j] = acc[ii * n + j] as f32;
            }
        }
    }
}

/// Accumulate rows `row0..row0+ilen` of `a @ b` into the f64 tile `acc`
/// (`ilen × n`, pre-zeroed). Per column the fold is strictly k-ascending
/// — the four-wide unroll issues sequential adds — which makes the tile
/// bitwise equal to [`matmul_naive`] and slice-invariant over exact-zero
/// `a` entries. With the `simd` feature an arch-specific body runs first
/// (`simd.rs`); it replays this exact per-column accumulation order, so
/// the dispatch never changes a single bit.
fn acc_tile_f32(acc: &mut [f64], a: &[f32], b: &[f32], row0: usize, ilen: usize, k: usize, n: usize) {
    #[cfg(feature = "simd")]
    if super::simd::acc_tile_f32(acc, a, b, row0, ilen, k, n) {
        return;
    }
    for kb in (0..k).step_by(TILE_K) {
        let klen = TILE_K.min(k - kb);
        for ii in 0..ilen {
            let arow = &a[(row0 + ii) * k + kb..][..klen];
            let accrow = &mut acc[ii * n..(ii + 1) * n];
            let mut kk = 0;
            while kk + 4 <= klen {
                let a0 = arow[kk] as f64;
                let a1 = arow[kk + 1] as f64;
                let a2 = arow[kk + 2] as f64;
                let a3 = arow[kk + 3] as f64;
                if a0 != 0.0 || a1 != 0.0 || a2 != 0.0 || a3 != 0.0 {
                    let b0 = &b[(kb + kk) * n..][..n];
                    let b1 = &b[(kb + kk + 1) * n..][..n];
                    let b2 = &b[(kb + kk + 2) * n..][..n];
                    let b3 = &b[(kb + kk + 3) * n..][..n];
                    // four *sequential* adds per column (not one grouped
                    // sum): per-column accumulation is then a strict
                    // k-ascending fold, identical to `matmul_naive`, and
                    // dropping exact-zero a-terms (naive's skip, or the
                    // shrink-as-you-train slicing of zeroed channels)
                    // cannot change a bit of the result.
                    for j in 0..n {
                        accrow[j] += a0 * b0[j] as f64;
                        accrow[j] += a1 * b1[j] as f64;
                        accrow[j] += a2 * b2[j] as f64;
                        accrow[j] += a3 * b3[j] as f64;
                    }
                }
                kk += 4;
            }
            while kk < klen {
                let av = arow[kk] as f64;
                if av != 0.0 {
                    let brow = &b[(kb + kk) * n..][..n];
                    for j in 0..n {
                        accrow[j] += av * brow[j] as f64;
                    }
                }
                kk += 1;
            }
        }
    }
}

/// `a[m,k]^T @ b[m,n] -> [k,n]` (weight-gradient shape) — threaded over
/// output rows, f64 accumulation in the naive i-ascending order.
pub fn matmul_tn(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; k * n];
    matmul_tn_into(&mut out, a, b, m, k, n);
    out
}

/// [`matmul_tn`] writing into a caller-provided (arena) buffer.
pub fn matmul_tn_into(out: &mut [f32], a: &[f32], b: &[f32], m: usize, k: usize, n: usize) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), m * n);
    assert_eq!(out.len(), k * n);
    if out.is_empty() {
        return;
    }
    if m == 0 {
        zero(out);
        return;
    }
    let nt = kernel_threads(m * k * n, k);
    if nt <= 1 {
        matmul_tn_rows(out, a, b, 0, m, k, n);
        return;
    }
    let chunk = k.div_ceil(nt);
    std::thread::scope(|sc| {
        for (ti, oc) in out.chunks_mut(chunk * n).enumerate() {
            sc.spawn(move || matmul_tn_rows(oc, a, b, ti * chunk, m, k, n));
        }
    });
}

/// Output rows `k0..k0 + out.len()/n` of `a^T @ b`: per element the sum
/// runs over i ascending, exactly the naive order, for any partition.
fn matmul_tn_rows(out: &mut [f32], a: &[f32], b: &[f32], k0: usize, m: usize, k: usize, n: usize) {
    let klen = out.len() / n;
    let mut acc = vec![0.0f64; klen * n];
    acc_tn_f32(&mut acc, a, b, k0, klen, m, k, n);
    for (o, &v) in out.iter_mut().zip(acc.iter()) {
        *o = v as f32;
    }
}

/// Accumulation body of [`matmul_tn_rows`]; the `simd` dispatch replays
/// the identical i-ascending per-column order (see `simd.rs`).
#[allow(clippy::too_many_arguments)]
fn acc_tn_f32(
    acc: &mut [f64],
    a: &[f32],
    b: &[f32],
    k0: usize,
    klen: usize,
    m: usize,
    k: usize,
    n: usize,
) {
    #[cfg(feature = "simd")]
    if super::simd::acc_tn_f32(acc, a, b, k0, klen, m, k, n) {
        return;
    }
    for i in 0..m {
        let arow = &a[i * k + k0..][..klen];
        let brow = &b[i * n..(i + 1) * n];
        for (kk, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let av = av as f64;
            let accrow = &mut acc[kk * n..(kk + 1) * n];
            for j in 0..n {
                accrow[j] += av * brow[j] as f64;
            }
        }
    }
}

/// `a[m,k] @ b[n,k]^T -> [m,n]` (input-gradient shape): both operands are
/// walked along contiguous rows, so this is a dot per output element —
/// j-blocked so a panel of `b` rows is reused across a block of `a` rows,
/// and threaded over output rows.
pub fn matmul_nt(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; m * n];
    matmul_nt_into(&mut out, a, b, m, k, n);
    out
}

/// [`matmul_nt`] writing into a caller-provided (arena) buffer.
pub fn matmul_nt_into(out: &mut [f32], a: &[f32], b: &[f32], m: usize, k: usize, n: usize) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), n * k);
    assert_eq!(out.len(), m * n);
    if out.is_empty() {
        return;
    }
    let nt = kernel_threads(m * k * n, m);
    if nt <= 1 {
        matmul_nt_rows(out, a, b, 0, k, n);
        return;
    }
    let chunk = m.div_ceil(nt);
    std::thread::scope(|sc| {
        for (ti, oc) in out.chunks_mut(chunk * n).enumerate() {
            sc.spawn(move || matmul_nt_rows(oc, a, b, ti * chunk, k, n));
        }
    });
}

fn matmul_nt_rows(out: &mut [f32], a: &[f32], b: &[f32], i0: usize, k: usize, n: usize) {
    const TILE_J: usize = 8;
    let rows = out.len() / n;
    for ib in (0..rows).step_by(TILE_I) {
        let ilen = TILE_I.min(rows - ib);
        for jb in (0..n).step_by(TILE_J) {
            let jlen = TILE_J.min(n - jb);
            for ii in 0..ilen {
                let arow = &a[(i0 + ib + ii) * k..][..k];
                let orow = &mut out[(ib + ii) * n..(ib + ii + 1) * n];
                for j in jb..jb + jlen {
                    orow[j] = dot(arow, &b[j * k..(j + 1) * k]) as f32;
                }
            }
        }
    }
}

/// Reference `a[m,k] @ b[k,n]`: the naive triple loop with a per-row f64
/// accumulator. Ground truth for the tiled kernels and the baseline the
/// runtime bench measures speedups against.
pub fn matmul_naive(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    let mut out = vec![0.0f32; m * n];
    let mut acc = vec![0.0f64; n];
    for i in 0..m {
        for v in acc.iter_mut() {
            *v = 0.0;
        }
        for kk in 0..k {
            let av = a[i * k + kk] as f64;
            if av == 0.0 {
                continue;
            }
            let brow = &b[kk * n..(kk + 1) * n];
            for j in 0..n {
                acc[j] += av * brow[j] as f64;
            }
        }
        let orow = &mut out[i * n..(i + 1) * n];
        for j in 0..n {
            orow[j] = acc[j] as f32;
        }
    }
    out
}

/// Reference `a[m,k]^T @ b[m,n] -> [k,n]` (see [`matmul_naive`]).
pub fn matmul_tn_naive(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), m * n);
    let mut acc = vec![0.0f64; k * n];
    for i in 0..m {
        let brow = &b[i * n..(i + 1) * n];
        for kk in 0..k {
            let av = a[i * k + kk] as f64;
            if av == 0.0 {
                continue;
            }
            let arow = &mut acc[kk * n..(kk + 1) * n];
            for j in 0..n {
                arow[j] += av * brow[j] as f64;
            }
        }
    }
    acc.iter().map(|&v| v as f32).collect()
}

/// Reference `a[m,k] @ b[n,k]^T -> [m,n]` (see [`matmul_naive`]).
pub fn matmul_nt_naive(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), n * k);
    let mut out = vec![0.0f32; m * n];
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let orow = &mut out[i * n..(i + 1) * n];
        for j in 0..n {
            orow[j] = dot(arow, &b[j * k..(j + 1) * k]) as f32;
        }
    }
    out
}

// ----------------------------------------------------------- convolution
//
// NHWC inputs, HWIO weights (the zoo's layout, see python/compile/models/
// common.py). Conv executes as im2col + GEMM; `col2im` is the transpose
// scatter used by the input gradient.

/// Output extent and low-side padding of one spatial dim.
/// `same = true` mirrors XLA SAME semantics (out = ceil(in/stride),
/// pad_total split low-biased); `false` is VALID (no padding).
pub fn conv_out_dim(h: usize, k: usize, stride: usize, same: bool) -> (usize, usize) {
    if same {
        let out = h.div_ceil(stride);
        let total = ((out - 1) * stride + k).max(h) - h;
        (out, total / 2)
    } else {
        ((h - k) / stride + 1, 0)
    }
}

/// `x[b,h,w,c] -> cols[b*ho*wo, k*k*c]`, column index `(kh*k + kw)*c + ci`
/// (matches the HWIO weight flattened to `[k*k*c, cout]`). Out-of-image
/// taps stay zero.
#[allow(clippy::too_many_arguments)]
pub fn im2col(
    x: &[f32],
    bsz: usize,
    h: usize,
    w: usize,
    c: usize,
    k: usize,
    stride: usize,
    pad: usize,
    ho: usize,
    wo: usize,
) -> Vec<f32> {
    let mut cols = vec![0.0f32; bsz * ho * wo * k * k * c];
    im2col_into(&mut cols, x, bsz, h, w, c, k, stride, pad, ho, wo);
    cols
}

/// [`im2col`] writing into a caller-provided (arena) buffer; the buffer is
/// re-zeroed here, so it may carry stale values.
#[allow(clippy::too_many_arguments)]
pub fn im2col_into(
    cols: &mut [f32],
    x: &[f32],
    bsz: usize,
    h: usize,
    w: usize,
    c: usize,
    k: usize,
    stride: usize,
    pad: usize,
    ho: usize,
    wo: usize,
) {
    assert_eq!(x.len(), bsz * h * w * c);
    assert_eq!(cols.len(), bsz * ho * wo * k * k * c);
    zero(cols);
    let rowlen = k * k * c;
    for bi in 0..bsz {
        for oh in 0..ho {
            for kh in 0..k {
                let ih = (oh * stride + kh) as isize - pad as isize;
                if ih < 0 || ih >= h as isize {
                    continue;
                }
                for ow in 0..wo {
                    let r = (bi * ho + oh) * wo + ow;
                    for kw in 0..k {
                        let iw = (ow * stride + kw) as isize - pad as isize;
                        if iw < 0 || iw >= w as isize {
                            continue;
                        }
                        let src = ((bi * h + ih as usize) * w + iw as usize) * c;
                        let dst = r * rowlen + (kh * k + kw) * c;
                        cols[dst..dst + c].copy_from_slice(&x[src..src + c]);
                    }
                }
            }
        }
    }
}

/// Transpose of [`im2col`]: scatter-add column gradients back onto the
/// input image. `gcols` is `[b*ho*wo, k*k*c]`.
#[allow(clippy::too_many_arguments)]
pub fn col2im(
    gcols: &[f32],
    bsz: usize,
    h: usize,
    w: usize,
    c: usize,
    k: usize,
    stride: usize,
    pad: usize,
    ho: usize,
    wo: usize,
) -> Vec<f32> {
    let mut gx = vec![0.0f32; bsz * h * w * c];
    col2im_into(&mut gx, gcols, bsz, h, w, c, k, stride, pad, ho, wo);
    gx
}

/// [`col2im`] writing into a caller-provided (arena) buffer; the buffer is
/// re-zeroed here before the scatter-add, so it may carry stale values.
#[allow(clippy::too_many_arguments)]
pub fn col2im_into(
    gx: &mut [f32],
    gcols: &[f32],
    bsz: usize,
    h: usize,
    w: usize,
    c: usize,
    k: usize,
    stride: usize,
    pad: usize,
    ho: usize,
    wo: usize,
) {
    assert_eq!(gcols.len(), bsz * ho * wo * k * k * c);
    assert_eq!(gx.len(), bsz * h * w * c);
    zero(gx);
    let rowlen = k * k * c;
    for bi in 0..bsz {
        for oh in 0..ho {
            for kh in 0..k {
                let ih = (oh * stride + kh) as isize - pad as isize;
                if ih < 0 || ih >= h as isize {
                    continue;
                }
                for ow in 0..wo {
                    let r = (bi * ho + oh) * wo + ow;
                    for kw in 0..k {
                        let iw = (ow * stride + kw) as isize - pad as isize;
                        if iw < 0 || iw >= w as isize {
                            continue;
                        }
                        let dst = ((bi * h + ih as usize) * w + iw as usize) * c;
                        let src = r * rowlen + (kh * k + kw) * c;
                        axpy(1.0, &gcols[src..src + c], &mut gx[dst..dst + c]);
                    }
                }
            }
        }
    }
}

// -------------------------------------------------------- normalizations
//
// One shared shape: `x[rows, c]` flat. LayerNorm normalizes each row over
// its `c` entries; BatchNorm normalizes each of the `c` channels over the
// `rows` axis (batch statistics, stateless — DESIGN.md decision 3).

/// Saved forward state the normalization backward passes consume.
#[derive(Debug, Clone)]
pub struct NormAux {
    /// Normalized activations (x - mu) / sqrt(var + eps), same layout as x.
    pub xhat: Vec<f32>,
    /// 1/sqrt(var + eps): one entry per row (layernorm) or per channel
    /// (batchnorm).
    pub inv: Vec<f32>,
}

/// LayerNorm forward: y = xhat * gamma + beta per row.
pub fn layernorm_rows(
    x: &[f32],
    gamma: &[f32],
    beta: &[f32],
    rows: usize,
    c: usize,
    eps: f32,
) -> (Vec<f32>, NormAux) {
    assert_eq!(x.len(), rows * c);
    assert_eq!(gamma.len(), c);
    assert_eq!(beta.len(), c);
    let mut y = vec![0.0f32; rows * c];
    let mut xhat = vec![0.0f32; rows * c];
    let mut inv = vec![0.0f32; rows];
    for r in 0..rows {
        let xr = &x[r * c..(r + 1) * c];
        let mut mu = 0.0f64;
        for &v in xr {
            mu += v as f64;
        }
        mu /= c as f64;
        let mut var = 0.0f64;
        for &v in xr {
            let dlt = v as f64 - mu;
            var += dlt * dlt;
        }
        var /= c as f64;
        let iv = 1.0 / (var + eps as f64).sqrt();
        inv[r] = iv as f32;
        for j in 0..c {
            let xh = ((xr[j] as f64 - mu) * iv) as f32;
            xhat[r * c + j] = xh;
            y[r * c + j] = xh * gamma[j] + beta[j];
        }
    }
    (y, NormAux { xhat, inv })
}

/// LayerNorm backward: returns (dx, dgamma, dbeta).
pub fn layernorm_bwd_rows(
    gamma: &[f32],
    cot: &[f32],
    aux: &NormAux,
    rows: usize,
    c: usize,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    assert_eq!(cot.len(), rows * c);
    let mut gx = vec![0.0f32; rows * c];
    let mut ggamma64 = vec![0.0f64; c];
    let mut gbeta64 = vec![0.0f64; c];
    for r in 0..rows {
        let cr = &cot[r * c..(r + 1) * c];
        let xh = &aux.xhat[r * c..(r + 1) * c];
        let mut s1 = 0.0f64; // sum dxhat
        let mut s2 = 0.0f64; // sum dxhat * xhat
        for j in 0..c {
            let dxh = (cr[j] * gamma[j]) as f64;
            s1 += dxh;
            s2 += dxh * xh[j] as f64;
            ggamma64[j] += (cr[j] * xh[j]) as f64;
            gbeta64[j] += cr[j] as f64;
        }
        let m = c as f64;
        let iv = aux.inv[r] as f64;
        for j in 0..c {
            let dxh = (cr[j] * gamma[j]) as f64;
            gx[r * c + j] = (iv / m * (m * dxh - s1 - xh[j] as f64 * s2)) as f32;
        }
    }
    let ggamma = ggamma64.iter().map(|&v| v as f32).collect();
    let gbeta = gbeta64.iter().map(|&v| v as f32).collect();
    (gx, ggamma, gbeta)
}

/// BatchNorm forward over the rows axis (per-channel batch statistics).
pub fn batchnorm_rows(
    x: &[f32],
    gamma: &[f32],
    beta: &[f32],
    rows: usize,
    c: usize,
    eps: f32,
) -> (Vec<f32>, NormAux) {
    assert_eq!(x.len(), rows * c);
    assert_eq!(gamma.len(), c);
    assert_eq!(beta.len(), c);
    let mut mu = vec![0.0f64; c];
    for r in 0..rows {
        for j in 0..c {
            mu[j] += x[r * c + j] as f64;
        }
    }
    for v in mu.iter_mut() {
        *v /= rows as f64;
    }
    let mut var = vec![0.0f64; c];
    for r in 0..rows {
        for j in 0..c {
            let dlt = x[r * c + j] as f64 - mu[j];
            var[j] += dlt * dlt;
        }
    }
    let inv: Vec<f32> = var
        .iter()
        .map(|&v| (1.0 / (v / rows as f64 + eps as f64).sqrt()) as f32)
        .collect();
    let mut y = vec![0.0f32; rows * c];
    let mut xhat = vec![0.0f32; rows * c];
    for r in 0..rows {
        for j in 0..c {
            let xh = ((x[r * c + j] as f64 - mu[j]) * inv[j] as f64) as f32;
            xhat[r * c + j] = xh;
            y[r * c + j] = xh * gamma[j] + beta[j];
        }
    }
    (y, NormAux { xhat, inv })
}

/// BatchNorm backward: returns (dx, dgamma, dbeta).
pub fn batchnorm_bwd_rows(
    gamma: &[f32],
    cot: &[f32],
    aux: &NormAux,
    rows: usize,
    c: usize,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    assert_eq!(cot.len(), rows * c);
    let mut s1 = vec![0.0f64; c]; // sum dxhat per channel
    let mut s2 = vec![0.0f64; c]; // sum dxhat * xhat per channel
    let mut ggamma64 = vec![0.0f64; c];
    let mut gbeta64 = vec![0.0f64; c];
    for r in 0..rows {
        for j in 0..c {
            let ct = cot[r * c + j];
            let dxh = (ct * gamma[j]) as f64;
            s1[j] += dxh;
            s2[j] += dxh * aux.xhat[r * c + j] as f64;
            ggamma64[j] += (ct * aux.xhat[r * c + j]) as f64;
            gbeta64[j] += ct as f64;
        }
    }
    let m = rows as f64;
    let mut gx = vec![0.0f32; rows * c];
    for r in 0..rows {
        for j in 0..c {
            let dxh = (cot[r * c + j] * gamma[j]) as f64;
            let iv = aux.inv[j] as f64;
            gx[r * c + j] =
                (iv / m * (m * dxh - s1[j] - aux.xhat[r * c + j] as f64 * s2[j])) as f32;
        }
    }
    let ggamma = ggamma64.iter().map(|&v| v as f32).collect();
    let gbeta = gbeta64.iter().map(|&v| v as f32).collect();
    (gx, ggamma, gbeta)
}

// ---------------------------------------------------------- softmax/gelu

/// Row-wise softmax in place (`x[rows, n]`), f64 denominator.
pub fn softmax_rows(x: &mut [f32], rows: usize, n: usize) {
    assert_eq!(x.len(), rows * n);
    for r in 0..rows {
        let row = &mut x[r * n..(r + 1) * n];
        let max = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0.0f64;
        for v in row.iter_mut() {
            *v = (*v - max).exp();
            sum += *v as f64;
        }
        for v in row.iter_mut() {
            *v = (*v as f64 / sum) as f32;
        }
    }
}

/// Softmax backward per row: dx = p * (cot - <cot, p>).
pub fn softmax_bwd_rows(p: &[f32], cot: &[f32], rows: usize, n: usize) -> Vec<f32> {
    assert_eq!(p.len(), rows * n);
    assert_eq!(cot.len(), rows * n);
    let mut gx = vec![0.0f32; rows * n];
    for r in 0..rows {
        let pr = &p[r * n..(r + 1) * n];
        let cr = &cot[r * n..(r + 1) * n];
        let s = dot(pr, cr);
        for j in 0..n {
            gx[r * n + j] = pr[j] * (cr[j] as f64 - s) as f32;
        }
    }
    gx
}

const GELU_SQRT_2_OVER_PI: f32 = 0.797_884_6;
const GELU_COEF: f32 = 0.044715;

/// Tanh-approximated GELU (the `jax.nn.gelu` default the zoo uses).
pub fn gelu(x: f32) -> f32 {
    let u = GELU_SQRT_2_OVER_PI * (x + GELU_COEF * x * x * x);
    0.5 * x * (1.0 + u.tanh())
}

/// d gelu / dx of the tanh approximation.
pub fn gelu_grad(x: f32) -> f32 {
    let u = GELU_SQRT_2_OVER_PI * (x + GELU_COEF * x * x * x);
    let t = u.tanh();
    let du = GELU_SQRT_2_OVER_PI * (1.0 + 3.0 * GELU_COEF * x * x);
    0.5 * (1.0 + t) + 0.5 * x * (1.0 - t * t) * du
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::tile::{configured_threads, serial_scope, set_threads, THREAD_TEST_LOCK};
    use crate::util::prop;

    #[test]
    fn axpy_basic() {
        let mut y = vec![1.0, 2.0];
        axpy(2.0, &[3.0, 4.0], &mut y);
        assert_eq!(y, vec![7.0, 10.0]);
    }

    #[test]
    fn cosine_signs() {
        assert!((cosine(&[1.0, 0.0], &[1.0, 0.0]) - 1.0).abs() < 1e-9);
        assert!((cosine(&[1.0, 0.0], &[-1.0, 0.0]) + 1.0).abs() < 1e-9);
        assert!(cosine(&[1.0, 0.0], &[0.0, 1.0]).abs() < 1e-9);
        assert_eq!(cosine(&[0.0, 0.0], &[1.0, 1.0]), 0.0);
    }

    #[test]
    fn norms() {
        assert!((norm2(&[3.0, 4.0]) - 5.0).abs() < 1e-9);
        assert!((mean_abs(&[-1.0, 3.0]) - 2.0).abs() < 1e-9);
        assert_eq!(max_abs(&[-5.0, 2.0]), 5.0);
        assert_eq!(mean_abs(&[]), 0.0);
    }

    #[test]
    fn strided_roundtrip() {
        let data = vec![0., 1., 2., 3., 4., 5.];
        let mut buf = Vec::new();
        gather_strided(&data, 1, 2, &mut buf);
        assert_eq!(buf, vec![1., 3., 5.]);
        let mut d2 = data.clone();
        scatter_strided(&mut d2, 1, 2, &[10., 30., 50.]);
        assert_eq!(d2, vec![0., 10., 2., 30., 4., 50.]);
    }

    #[test]
    fn prop_cauchy_schwarz() {
        prop::check(
            50,
            |g| {
                let n = g.size(64);
                (g.vec_normal(n, 2.0), g.vec_normal(n, 2.0))
            },
            |(a, b)| {
                let c = cosine(a, b);
                if (-1.0..=1.0).contains(&c) {
                    Ok(())
                } else {
                    Err(format!("cosine out of range: {c}"))
                }
            },
        );
    }

    #[test]
    fn empty_slices_are_no_ops() {
        let mut y: Vec<f32> = vec![];
        axpy(2.0, &[], &mut y);
        scale_add(0.5, &mut y, 2.0, &[]);
        assert!(y.is_empty());
        assert_eq!(dot(&[], &[]), 0.0);
        assert_eq!(norm2(&[]), 0.0);
        assert_eq!(max_abs(&[]), 0.0);
        let mut buf = vec![1.0f32];
        gather_strided(&[], 0, 3, &mut buf);
        assert!(buf.is_empty());
        scatter_strided(&mut [], 0, 3, &[]);
    }

    #[test]
    #[should_panic]
    fn axpy_length_mismatch_panics() {
        let mut y = vec![0.0f32; 3];
        axpy(1.0, &[1.0, 2.0], &mut y);
    }

    #[test]
    #[should_panic]
    fn scale_add_length_mismatch_panics() {
        let mut y = vec![0.0f32; 2];
        scale_add(1.0, &mut y, 1.0, &[1.0, 2.0, 3.0]);
    }

    #[test]
    #[should_panic]
    fn dot_length_mismatch_panics() {
        dot(&[1.0, 2.0], &[1.0]);
    }

    #[test]
    fn scale_add_blends() {
        let mut y = vec![1.0f32, -2.0];
        scale_add(0.5, &mut y, 2.0, &[3.0, 4.0]);
        assert_eq!(y, vec![6.5, 7.0]);
    }

    #[test]
    fn dot_accumulates_in_f64_on_large_inputs() {
        // 1M summands of 1e-2: an f32 accumulator drifts by ~1e-4 relative
        // once the partial sum dwarfs each term; the f64 path stays exact
        // to ~1e-12 relative.
        let n = 1_000_000usize;
        let v = vec![0.1f32; n];
        let got = dot(&v, &v);
        let want = (0.1f32 as f64) * (0.1f32 as f64) * n as f64;
        assert!(
            (got - want).abs() / want < 1e-9,
            "f64 accumulation broken: {got} vs {want}"
        );
        // norm2 inherits the same accumulator
        let norm_want = want.sqrt();
        assert!((norm2(&v) - norm_want).abs() / norm_want < 1e-9);
        // cancellation: big + many smalls - big must recover the smalls
        let mut w = vec![1.0f32; n + 2];
        w[0] = 1.0e8;
        w[n + 1] = -1.0e8;
        let ones = vec![1.0f32; n + 2];
        let got = dot(&w, &ones);
        assert!((got - n as f64).abs() < 1e-3, "cancellation lost: {got}");
    }

    #[test]
    fn prop_axpy_linear() {
        prop::check(
            30,
            |g| {
                let n = g.size(32);
                (g.vec_normal(n, 1.0), g.vec_normal(n, 1.0), g.f32_in(-2.0, 2.0))
            },
            |(x, y, a)| {
                let mut y1 = y.clone();
                axpy(*a, x, &mut y1);
                for i in 0..x.len() {
                    let want = y[i] + a * x[i];
                    if (y1[i] - want).abs() > 1e-5 {
                        return Err(format!("i={i}: {} vs {want}", y1[i]));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn matmul_hand_values() {
        // [2,3] @ [3,2]
        let a = [1., 2., 3., 4., 5., 6.];
        let b = [7., 8., 9., 10., 11., 12.];
        assert_eq!(matmul(&a, &b, 2, 3, 2), vec![58., 64., 139., 154.]);
        // a^T @ a = gram matrix of columns
        let g = matmul_tn(&a, &a, 2, 3, 3);
        assert_eq!(g[0], 1. + 16.); // col0 . col0
        assert_eq!(g[1], 2. + 20.); // col0 . col1
        // a @ a^T = gram matrix of rows
        let r = matmul_nt(&a, &a, 2, 3, 2);
        assert_eq!(r, vec![14., 32., 32., 77.]);
    }

    #[test]
    fn prop_matmul_variants_agree_with_transposed_inputs() {
        // matmul_tn(a, c) == matmul(a^T, c) and matmul_nt(a, b) ==
        // matmul(a, b^T): the three kernels implement one contraction.
        prop::check(
            30,
            |g| {
                let m = g.size(6);
                let k = g.size(6);
                let n = g.size(6);
                (
                    m,
                    k,
                    n,
                    g.vec_normal(m * k, 1.0), // a[m,k]
                    g.vec_normal(m * n, 1.0), // c[m,n]
                    g.vec_normal(n * k, 1.0), // b[n,k]
                )
            },
            |(m, k, n, a, c, b)| {
                let (m, k, n) = (*m, *k, *n);
                let mut at = vec![0.0f32; k * m];
                for i in 0..m {
                    for j in 0..k {
                        at[j * m + i] = a[i * k + j];
                    }
                }
                let want = matmul(&at, c, k, m, n);
                let got = matmul_tn(a, c, m, k, n);
                for i in 0..want.len() {
                    if (want[i] - got[i]).abs() > 1e-4 {
                        return Err(format!("tn[{i}]: {} vs {}", got[i], want[i]));
                    }
                }
                let mut bt = vec![0.0f32; k * n];
                for i in 0..n {
                    for j in 0..k {
                        bt[j * n + i] = b[i * k + j];
                    }
                }
                let want = matmul(a, &bt, m, k, n);
                let got = matmul_nt(a, b, m, k, n);
                for i in 0..want.len() {
                    if (want[i] - got[i]).abs() > 1e-4 {
                        return Err(format!("nt[{i}]: {} vs {}", got[i], want[i]));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_tiled_matmuls_match_naive_reference_across_thread_counts() {
        // the tiled/threaded kernels against the naive f64 triple loops,
        // over random shapes — including row counts large enough to cross
        // tile borders and the thread-spawn threshold — at 1/2/4 workers
        let _guard = THREAD_TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        let prev = configured_threads();
        for &threads in &[1usize, 2, 4] {
            set_threads(threads);
            prop::check(
                12,
                |g| {
                    // every few cases, a shape big enough to actually spawn
                    let big = g.f32_in(0.0, 1.0) < 0.4;
                    let m = if big { 64 + g.size(512) } else { g.size(40) };
                    let k = g.size(if big { 96 } else { 24 });
                    let n = g.size(if big { 48 } else { 24 });
                    let mut a = g.vec_normal(m * k, 1.0);
                    // real inputs are relu-sparse: exercise the zero-skip
                    for v in a.iter_mut() {
                        if *v < 0.0 {
                            *v = 0.0;
                        }
                    }
                    let b = g.vec_normal(k * n, 1.0);
                    let c = g.vec_normal(m * n, 1.0);
                    let bt = g.vec_normal(n * k, 1.0);
                    (m, k, n, a, b, c, bt)
                },
                |(m, k, n, a, b, c, bt)| {
                    let (m, k, n) = (*m, *k, *n);
                    let pairs = [
                        ("matmul", matmul(a, b, m, k, n), matmul_naive(a, b, m, k, n)),
                        ("matmul_tn", matmul_tn(a, c, m, k, n), matmul_tn_naive(a, c, m, k, n)),
                        ("matmul_nt", matmul_nt(a, bt, m, k, n), matmul_nt_naive(a, bt, m, k, n)),
                    ];
                    for (name, got, want) in &pairs {
                        for i in 0..want.len() {
                            if (got[i] - want[i]).abs() > 1e-6 * (1.0 + want[i].abs()) {
                                return Err(format!(
                                    "{name}[{i}] (threads={threads}, m={m} k={k} n={n}): \
                                     tiled {} vs naive {}",
                                    got[i], want[i]
                                ));
                            }
                        }
                    }
                    Ok(())
                },
            );
        }
        set_threads(prev);
    }

    #[test]
    fn tiled_matmuls_are_bitwise_thread_count_invariant() {
        // the determinism contract: identical bits at every worker count
        let _guard = THREAD_TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        let prev = configured_threads();
        let mut rng = crate::util::rng::Rng::new(17);
        let (m, k, n) = (300, 70, 40);
        let mut a = vec![0.0f32; m * k];
        rng.fill_normal(&mut a, 1.0);
        let mut b = vec![0.0f32; k * n];
        rng.fill_normal(&mut b, 1.0);
        let mut c = vec![0.0f32; m * n];
        rng.fill_normal(&mut c, 1.0);
        let mut bt = vec![0.0f32; n * k];
        rng.fill_normal(&mut bt, 1.0);
        set_threads(1);
        let base = (
            matmul(&a, &b, m, k, n),
            matmul_tn(&a, &c, m, k, n),
            matmul_nt(&a, &bt, m, k, n),
        );
        for threads in [2usize, 3, 4, 8] {
            set_threads(threads);
            assert_eq!(base.0, matmul(&a, &b, m, k, n), "matmul @ {threads} threads");
            assert_eq!(base.1, matmul_tn(&a, &c, m, k, n), "matmul_tn @ {threads} threads");
            assert_eq!(base.2, matmul_nt(&a, &bt, m, k, n), "matmul_nt @ {threads} threads");
        }
        // serial_scope pins nested kernels to one thread, same bits
        set_threads(4);
        let nested = serial_scope(|| matmul(&a, &b, m, k, n));
        assert_eq!(base.0, nested);
        set_threads(prev);
    }

    #[test]
    fn im2col_into_reuses_dirty_buffers() {
        let mut rng = crate::util::rng::Rng::new(23);
        let (bsz, h, w, c, k, stride) = (2, 5, 4, 3, 3, 1);
        let (ho, pad) = conv_out_dim(h, k, stride, true);
        let (wo, _) = conv_out_dim(w, k, stride, true);
        let mut x = vec![0.0f32; bsz * h * w * c];
        rng.fill_normal(&mut x, 1.0);
        let want = im2col(&x, bsz, h, w, c, k, stride, pad, ho, wo);
        let mut dirty = vec![7.0f32; want.len()];
        im2col_into(&mut dirty, &x, bsz, h, w, c, k, stride, pad, ho, wo);
        assert_eq!(want, dirty);
    }

    /// Naive direct convolution (independent of the im2col path).
    #[allow(clippy::too_many_arguments)]
    fn conv_direct(
        x: &[f32],
        w: &[f32],
        bsz: usize,
        h: usize,
        wd: usize,
        cin: usize,
        cout: usize,
        k: usize,
        stride: usize,
        same: bool,
    ) -> Vec<f32> {
        let (ho, pad) = conv_out_dim(h, k, stride, same);
        let (wo, _) = conv_out_dim(wd, k, stride, same);
        let mut y = vec![0.0f32; bsz * ho * wo * cout];
        for bi in 0..bsz {
            for oh in 0..ho {
                for ow in 0..wo {
                    for kh in 0..k {
                        let ih = (oh * stride + kh) as isize - pad as isize;
                        if ih < 0 || ih >= h as isize {
                            continue;
                        }
                        for kw in 0..k {
                            let iw = (ow * stride + kw) as isize - pad as isize;
                            if iw < 0 || iw >= wd as isize {
                                continue;
                            }
                            for ci in 0..cin {
                                let xv = x[((bi * h + ih as usize) * wd + iw as usize) * cin + ci];
                                for co in 0..cout {
                                    y[((bi * ho + oh) * wo + ow) * cout + co] +=
                                        xv * w[((kh * k + kw) * cin + ci) * cout + co];
                                }
                            }
                        }
                    }
                }
            }
        }
        y
    }

    #[test]
    fn prop_conv_im2col_equals_direct() {
        // the interpreter's conv (im2col + GEMM) against a naive direct
        // convolution, over random shapes / kernels / strides / paddings
        prop::check(
            40,
            |g| {
                let h = 2 + g.size(6);
                let w = 2 + g.size(6);
                let cin = g.size(3);
                let cout = g.size(4);
                let k = if g.f32_in(0.0, 1.0) < 0.5 { 1 } else { 3 };
                let stride = g.size(2);
                let same = g.f32_in(0.0, 1.0) < 0.7;
                let bsz = g.size(2);
                let x = g.vec_normal(bsz * h * w * cin, 1.0);
                let wt = g.vec_normal(k * k * cin * cout, 1.0);
                (h, w, cin, cout, k, stride, same, bsz, x, wt)
            },
            |(h, w, cin, cout, k, stride, same, bsz, x, wt)| {
                let (h, w, cin, cout, k, stride, same, bsz) =
                    (*h, *w, *cin, *cout, *k, *stride, *same, *bsz);
                if !same && (h < k || w < k) {
                    return Ok(()); // VALID needs k to fit
                }
                let (ho, pad) = conv_out_dim(h, k, stride, same);
                let (wo, _) = conv_out_dim(w, k, stride, same);
                let cols = im2col(x, bsz, h, w, cin, k, stride, pad, ho, wo);
                let got = matmul(&cols, wt, bsz * ho * wo, k * k * cin, cout);
                let want = conv_direct(x, wt, bsz, h, w, cin, cout, k, stride, same);
                for i in 0..want.len() {
                    if (got[i] - want[i]).abs() > 1e-4 * (1.0 + want[i].abs()) {
                        return Err(format!(
                            "y[{i}]: im2col {} vs direct {} (h={h} w={w} k={k} s={stride} same={same})",
                            got[i], want[i]
                        ));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn col2im_is_im2col_transpose() {
        // <im2col(x), g> == <x, col2im(g)> for random g: the adjoint
        // property that makes the conv input gradient correct.
        let mut rng = crate::util::rng::Rng::new(5);
        let (bsz, h, w, c, k, stride) = (2, 5, 4, 3, 3, 2);
        let (ho, pad) = conv_out_dim(h, k, stride, true);
        let (wo, _) = conv_out_dim(w, k, stride, true);
        let mut x = vec![0.0f32; bsz * h * w * c];
        rng.fill_normal(&mut x, 1.0);
        let mut g = vec![0.0f32; bsz * ho * wo * k * k * c];
        rng.fill_normal(&mut g, 1.0);
        let cols = im2col(&x, bsz, h, w, c, k, stride, pad, ho, wo);
        let gx = col2im(&g, bsz, h, w, c, k, stride, pad, ho, wo);
        let lhs = dot(&cols, &g);
        let rhs = dot(&x, &gx);
        assert!((lhs - rhs).abs() < 1e-3 * (1.0 + lhs.abs()), "{lhs} vs {rhs}");
    }

    #[test]
    fn conv_out_dims_match_xla_same_semantics() {
        assert_eq!(conv_out_dim(16, 3, 1, true), (16, 1));
        assert_eq!(conv_out_dim(16, 3, 2, true), (8, 0)); // pad_total 1 => lo 0
        assert_eq!(conv_out_dim(16, 1, 2, true), (8, 0));
        assert_eq!(conv_out_dim(16, 4, 4, false), (4, 0));
        assert_eq!(conv_out_dim(8, 2, 2, false), (4, 0));
    }

    #[test]
    fn softmax_rows_basic() {
        let mut x = vec![0.0, 0.0, 1000.0, 1000.0];
        softmax_rows(&mut x, 2, 2);
        for &v in &x {
            assert!((v - 0.5).abs() < 1e-6, "{x:?}");
        }
        // backward of a uniform distribution with uniform cotangent is zero
        let g = softmax_bwd_rows(&x, &[1.0; 4], 2, 2);
        assert!(g.iter().all(|v| v.abs() < 1e-6), "{g:?}");
    }

    #[test]
    fn layernorm_normalizes_and_restores_affine() {
        let x = vec![1.0, 2.0, 3.0, -1.0, 0.0, 1.0];
        let gamma = vec![1.0; 3];
        let beta = vec![0.0; 3];
        let (y, aux) = layernorm_rows(&x, &gamma, &beta, 2, 3, 1e-5);
        for r in 0..2 {
            let row = &y[r * 3..(r + 1) * 3];
            let mean: f32 = row.iter().sum::<f32>() / 3.0;
            assert!(mean.abs() < 1e-5, "{row:?}");
        }
        assert_eq!(aux.inv.len(), 2);
        // gamma=2, beta=1 shifts the output affinely
        let (y2, _) = layernorm_rows(&x, &[2.0; 3], &[1.0; 3], 2, 3, 1e-5);
        for i in 0..6 {
            assert!((y2[i] - (2.0 * y[i] + 1.0)).abs() < 1e-5);
        }
    }

    #[test]
    fn batchnorm_normalizes_channels() {
        // channel 0 constant => xhat 0; channel 1 symmetric => +-1-ish
        let x = vec![5.0, -2.0, 5.0, 2.0];
        let (y, _) = batchnorm_rows(&x, &[1.0, 1.0], &[0.0, 0.0], 2, 2, 1e-5);
        assert!(y[0].abs() < 1e-3 && y[2].abs() < 1e-3, "{y:?}");
        assert!((y[1] + 1.0).abs() < 1e-2 && (y[3] - 1.0).abs() < 1e-2, "{y:?}");
    }

    #[test]
    fn norm_backward_matches_finite_differences() {
        let mut rng = crate::util::rng::Rng::new(11);
        let (rows, c) = (5, 4);
        let mut x = vec![0.0f32; rows * c];
        rng.fill_normal(&mut x, 1.0);
        let mut gamma = vec![1.0f32; c];
        rng.fill_normal(&mut gamma, 0.2);
        let beta = vec![0.1f32; c];
        let mut cot = vec![0.0f32; rows * c];
        rng.fill_normal(&mut cot, 1.0);
        let h = 1e-3f32;
        for layer in [true, false] {
            let fwd = |x: &[f32]| -> Vec<f32> {
                if layer {
                    layernorm_rows(x, &gamma, &beta, rows, c, 1e-5).0
                } else {
                    batchnorm_rows(x, &gamma, &beta, rows, c, 1e-5).0
                }
            };
            let aux = if layer {
                layernorm_rows(&x, &gamma, &beta, rows, c, 1e-5).1
            } else {
                batchnorm_rows(&x, &gamma, &beta, rows, c, 1e-5).1
            };
            let (gx, ggamma, gbeta) = if layer {
                layernorm_bwd_rows(&gamma, &cot, &aux, rows, c)
            } else {
                batchnorm_bwd_rows(&gamma, &cot, &aux, rows, c)
            };
            for &i in &[0usize, 7, rows * c - 1] {
                let mut xp = x.clone();
                xp[i] += h;
                let mut xm = x.clone();
                xm[i] -= h;
                let fd = (dot(&fwd(&xp), &cot) - dot(&fwd(&xm), &cot)) / (2.0 * h as f64);
                assert!(
                    (gx[i] as f64 - fd).abs() < 1e-2 * (1.0 + fd.abs()),
                    "layer={layer} gx[{i}]: {} vs {fd}",
                    gx[i]
                );
            }
            // gamma/beta gradients: direct sums, spot-check one entry
            let mut gp = gamma.clone();
            gp[1] += h;
            let fd = if layer {
                (dot(&layernorm_rows(&x, &gp, &beta, rows, c, 1e-5).0, &cot)
                    - dot(&layernorm_rows(&x, &gamma, &beta, rows, c, 1e-5).0, &cot))
                    / h as f64
            } else {
                (dot(&batchnorm_rows(&x, &gp, &beta, rows, c, 1e-5).0, &cot)
                    - dot(&batchnorm_rows(&x, &gamma, &beta, rows, c, 1e-5).0, &cot))
                    / h as f64
            };
            assert!(
                (ggamma[1] as f64 - fd).abs() < 1e-2 * (1.0 + fd.abs()),
                "layer={layer} ggamma: {} vs {fd}",
                ggamma[1]
            );
            assert!(gbeta.iter().zip(cot.chunks(c).fold(vec![0.0f32; c], |mut a, r| {
                for j in 0..c {
                    a[j] += r[j];
                }
                a
            }).iter()).all(|(g, s)| (g - s).abs() < 1e-4));
        }
    }

    #[test]
    fn gelu_grad_matches_finite_differences() {
        for &x in &[-2.0f32, -0.5, 0.0, 0.3, 1.7] {
            let h = 1e-3f32;
            let fd = (gelu(x + h) - gelu(x - h)) / (2.0 * h);
            assert!((gelu_grad(x) - fd).abs() < 1e-3, "x={x}: {} vs {fd}", gelu_grad(x));
        }
        assert!(gelu(0.0).abs() < 1e-7);
        assert!((gelu(10.0) - 10.0).abs() < 1e-4); // identity in the far tail
    }
}
