//! Flat-buffer numeric kernels for the QASSO hot path.
//!
//! These run once per optimizer step over every parameter, so they are
//! written as straight loops over slices (auto-vectorizable; no bounds
//! checks in the hot loops after the explicit `assert_eq!` length pins).

/// y += alpha * x
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    assert_eq!(x.len(), y.len());
    for i in 0..x.len() {
        y[i] += alpha * x[i];
    }
}

/// y = alpha * y + beta * x   (in-place scaled blend)
pub fn scale_add(alpha: f32, y: &mut [f32], beta: f32, x: &[f32]) {
    assert_eq!(x.len(), y.len());
    for i in 0..y.len() {
        y[i] = alpha * y[i] + beta * x[i];
    }
}

pub fn dot(x: &[f32], y: &[f32]) -> f64 {
    assert_eq!(x.len(), y.len());
    let mut s = 0.0f64;
    for i in 0..x.len() {
        s += x[i] as f64 * y[i] as f64;
    }
    s
}

pub fn norm2(x: &[f32]) -> f64 {
    dot(x, x).sqrt()
}

pub fn mean_abs(x: &[f32]) -> f64 {
    if x.is_empty() {
        return 0.0;
    }
    x.iter().map(|v| v.abs() as f64).sum::<f64>() / x.len() as f64
}

pub fn max_abs(x: &[f32]) -> f32 {
    x.iter().fold(0.0f32, |m, v| m.max(v.abs()))
}

/// cos of the angle between -a and -b (== angle between a and b).
/// Returns 0 when either vector is ~zero (the paper's rules then take the
/// "any positive value" branch, which is what 0 selects).
pub fn cosine(a: &[f32], b: &[f32]) -> f64 {
    let na = norm2(a);
    let nb = norm2(b);
    if na < 1e-12 || nb < 1e-12 {
        return 0.0;
    }
    (dot(a, b) / (na * nb)).clamp(-1.0, 1.0)
}

pub fn zero(x: &mut [f32]) {
    for v in x.iter_mut() {
        *v = 0.0;
    }
}

/// Strided view helpers for "structure" slices (one output channel of a
/// tensor whose prunable axis is last). Gathers into `out` (reused buffer).
pub fn gather_strided(data: &[f32], start: usize, stride: usize, out: &mut Vec<f32>) {
    out.clear();
    let mut i = start;
    while i < data.len() {
        out.push(data[i]);
        i += stride;
    }
}

pub fn scatter_strided(data: &mut [f32], start: usize, stride: usize, vals: &[f32]) {
    let mut i = start;
    let mut k = 0;
    while i < data.len() {
        data[i] = vals[k];
        i += stride;
        k += 1;
    }
    assert_eq!(k, vals.len());
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn axpy_basic() {
        let mut y = vec![1.0, 2.0];
        axpy(2.0, &[3.0, 4.0], &mut y);
        assert_eq!(y, vec![7.0, 10.0]);
    }

    #[test]
    fn cosine_signs() {
        assert!((cosine(&[1.0, 0.0], &[1.0, 0.0]) - 1.0).abs() < 1e-9);
        assert!((cosine(&[1.0, 0.0], &[-1.0, 0.0]) + 1.0).abs() < 1e-9);
        assert!(cosine(&[1.0, 0.0], &[0.0, 1.0]).abs() < 1e-9);
        assert_eq!(cosine(&[0.0, 0.0], &[1.0, 1.0]), 0.0);
    }

    #[test]
    fn norms() {
        assert!((norm2(&[3.0, 4.0]) - 5.0).abs() < 1e-9);
        assert!((mean_abs(&[-1.0, 3.0]) - 2.0).abs() < 1e-9);
        assert_eq!(max_abs(&[-5.0, 2.0]), 5.0);
        assert_eq!(mean_abs(&[]), 0.0);
    }

    #[test]
    fn strided_roundtrip() {
        let data = vec![0., 1., 2., 3., 4., 5.];
        let mut buf = Vec::new();
        gather_strided(&data, 1, 2, &mut buf);
        assert_eq!(buf, vec![1., 3., 5.]);
        let mut d2 = data.clone();
        scatter_strided(&mut d2, 1, 2, &[10., 30., 50.]);
        assert_eq!(d2, vec![0., 10., 2., 30., 4., 50.]);
    }

    #[test]
    fn prop_cauchy_schwarz() {
        prop::check(
            50,
            |g| {
                let n = g.size(64);
                (g.vec_normal(n, 2.0), g.vec_normal(n, 2.0))
            },
            |(a, b)| {
                let c = cosine(a, b);
                if (-1.0..=1.0).contains(&c) {
                    Ok(())
                } else {
                    Err(format!("cosine out of range: {c}"))
                }
            },
        );
    }

    #[test]
    fn empty_slices_are_no_ops() {
        let mut y: Vec<f32> = vec![];
        axpy(2.0, &[], &mut y);
        scale_add(0.5, &mut y, 2.0, &[]);
        assert!(y.is_empty());
        assert_eq!(dot(&[], &[]), 0.0);
        assert_eq!(norm2(&[]), 0.0);
        assert_eq!(max_abs(&[]), 0.0);
        let mut buf = vec![1.0f32];
        gather_strided(&[], 0, 3, &mut buf);
        assert!(buf.is_empty());
        scatter_strided(&mut [], 0, 3, &[]);
    }

    #[test]
    #[should_panic]
    fn axpy_length_mismatch_panics() {
        let mut y = vec![0.0f32; 3];
        axpy(1.0, &[1.0, 2.0], &mut y);
    }

    #[test]
    #[should_panic]
    fn scale_add_length_mismatch_panics() {
        let mut y = vec![0.0f32; 2];
        scale_add(1.0, &mut y, 1.0, &[1.0, 2.0, 3.0]);
    }

    #[test]
    #[should_panic]
    fn dot_length_mismatch_panics() {
        dot(&[1.0, 2.0], &[1.0]);
    }

    #[test]
    fn scale_add_blends() {
        let mut y = vec![1.0f32, -2.0];
        scale_add(0.5, &mut y, 2.0, &[3.0, 4.0]);
        assert_eq!(y, vec![6.5, 7.0]);
    }

    #[test]
    fn dot_accumulates_in_f64_on_large_inputs() {
        // 1M summands of 1e-2: an f32 accumulator drifts by ~1e-4 relative
        // once the partial sum dwarfs each term; the f64 path stays exact
        // to ~1e-12 relative.
        let n = 1_000_000usize;
        let v = vec![0.1f32; n];
        let got = dot(&v, &v);
        let want = (0.1f32 as f64) * (0.1f32 as f64) * n as f64;
        assert!(
            (got - want).abs() / want < 1e-9,
            "f64 accumulation broken: {got} vs {want}"
        );
        // norm2 inherits the same accumulator
        let norm_want = want.sqrt();
        assert!((norm2(&v) - norm_want).abs() / norm_want < 1e-9);
        // cancellation: big + many smalls - big must recover the smalls
        let mut w = vec![1.0f32; n + 2];
        w[0] = 1.0e8;
        w[n + 1] = -1.0e8;
        let ones = vec![1.0f32; n + 2];
        let got = dot(&w, &ones);
        assert!((got - n as f64).abs() < 1e-3, "cancellation lost: {got}");
    }

    #[test]
    fn prop_axpy_linear() {
        prop::check(
            30,
            |g| {
                let n = g.size(32);
                (g.vec_normal(n, 1.0), g.vec_normal(n, 1.0), g.f32_in(-2.0, 2.0))
            },
            |(x, y, a)| {
                let mut y1 = y.clone();
                axpy(*a, x, &mut y1);
                for i in 0..x.len() {
                    let want = y[i] + a * x[i];
                    if (y1[i] - want).abs() > 1e-5 {
                        return Err(format!("i={i}: {} vs {want}", y1[i]));
                    }
                }
                Ok(())
            },
        );
    }
}
