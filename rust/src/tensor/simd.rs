//! Arch-dispatched SIMD bodies for the hot GEMM accumulation tiles
//! (`cargo` feature `simd`).
//!
//! Every entry point mirrors one scalar accumulation tile in `ops.rs` /
//! `iops.rs` / `u4.rs` and returns `true` when an arch-specific body ran,
//! `false` when the caller must fall back to the scalar tile (unknown
//! arch, or AVX2 absent at runtime on x86_64). The scalar tiles remain
//! the always-available ground truth — the differential suite in
//! `rust/tests/test_kernels.rs` pins agreement with and without this
//! feature.
//!
//! Exactness contract (stronger than "close"): the vector bodies are
//! **bitwise identical** to the scalar tiles at every thread count.
//! - Integer tiles accumulate in i32, which is associative and exact
//!   under the `i8_gemm_fits_i32` gate, so any lane order is bitwise
//!   equal by construction.
//! - Float tiles vectorize **across j** (independent output columns):
//!   each f64 lane replays the scalar fold for its own column — a strict
//!   k-ascending sequence of mul-then-add steps, never FMA and never a
//!   grouped multi-term sum — so the per-column rounding sequence is
//!   unchanged from the scalar kernel (and, like it, invariant to
//!   dropping exact-zero k-terms, the shrink-as-you-train slicing case).
//! Dispatch is per accumulation tile: one cached `is_x86_feature_detected!`
//! check (a relaxed atomic load) per `TILE_I × n` block.
//!
//! Arch coverage: AVX2 on x86_64 (runtime-detected); NEON on aarch64
//! (baseline, always present). The mixed f32×i8 tile is AVX2-only for
//! now — on aarch64 it returns `false` and the scalar tile runs. Other
//! arches always fall back.

#![allow(clippy::too_many_arguments)]

use super::tile::TILE_K;

/// Vector body for `ops::matmul_rows`' accumulation tile: `acc[ilen, n]`
/// += rows `row0..row0+ilen` of `a[·,k] @ b[k,n]`, f64 accumulators.
pub(crate) fn acc_tile_f32(
    acc: &mut [f64],
    a: &[f32],
    b: &[f32],
    row0: usize,
    ilen: usize,
    k: usize,
    n: usize,
) -> bool {
    #[cfg(target_arch = "x86_64")]
    if std::arch::is_x86_feature_detected!("avx2") {
        unsafe { x86::acc_tile_f32(acc, a, b, row0, ilen, k, n) };
        return true;
    }
    #[cfg(target_arch = "aarch64")]
    {
        unsafe { neon::acc_tile_f32(acc, a, b, row0, ilen, k, n) };
        return true;
    }
    #[cfg(not(target_arch = "aarch64"))]
    {
        let _ = (acc, a, b, row0, ilen, k, n);
        false
    }
}

/// Vector body for `ops::matmul_tn_rows`' accumulation: `acc[klen, n]` +=
/// columns `k0..k0+klen` of `a[m,k]^T @ b[m,n]`, i ascending.
pub(crate) fn acc_tn_f32(
    acc: &mut [f64],
    a: &[f32],
    b: &[f32],
    k0: usize,
    klen: usize,
    m: usize,
    k: usize,
    n: usize,
) -> bool {
    #[cfg(target_arch = "x86_64")]
    if std::arch::is_x86_feature_detected!("avx2") {
        unsafe { x86::acc_tn_f32(acc, a, b, k0, klen, m, k, n) };
        return true;
    }
    #[cfg(target_arch = "aarch64")]
    {
        unsafe { neon::acc_tn_f32(acc, a, b, k0, klen, m, k, n) };
        return true;
    }
    #[cfg(not(target_arch = "aarch64"))]
    {
        let _ = (acc, a, b, k0, klen, m, k, n);
        false
    }
}

/// Vector body for `iops::acc_tile_i8`: exact i32 accumulation.
pub(crate) fn acc_tile_i8(
    acc: &mut [i32],
    a: &[i8],
    b: &[i8],
    row0: usize,
    ilen: usize,
    k: usize,
    n: usize,
) -> bool {
    #[cfg(target_arch = "x86_64")]
    if std::arch::is_x86_feature_detected!("avx2") {
        unsafe { x86::acc_tile_i8(acc, a, b, row0, ilen, k, n) };
        return true;
    }
    #[cfg(target_arch = "aarch64")]
    {
        unsafe { neon::acc_tile_i8(acc, a, b, row0, ilen, k, n) };
        return true;
    }
    #[cfg(not(target_arch = "aarch64"))]
    {
        let _ = (acc, a, b, row0, ilen, k, n);
        false
    }
}

/// Vector body for `iops::matmul_f32i8_rows`' accumulation tile (mixed
/// f32 activations × i8 levels, f64 accumulators). AVX2-only.
pub(crate) fn acc_tile_f32i8(
    acc: &mut [f64],
    a: &[f32],
    b: &[i8],
    row0: usize,
    ilen: usize,
    k: usize,
    n: usize,
) -> bool {
    #[cfg(target_arch = "x86_64")]
    if std::arch::is_x86_feature_detected!("avx2") {
        unsafe { x86::acc_tile_f32i8(acc, a, b, row0, ilen, k, n) };
        return true;
    }
    let _ = (acc, a, b, row0, ilen, k, n);
    false
}

/// Vector body for `u4::acc_tile_u4`: i8 activations × nibble-packed
/// weights, exact i32 accumulation, nibbles unpacked in-register.
/// `bp` is the packed panel, row stride `n.div_ceil(2)` bytes.
pub(crate) fn acc_tile_u4(
    acc: &mut [i32],
    a: &[i8],
    bp: &[u8],
    row0: usize,
    ilen: usize,
    k: usize,
    n: usize,
) -> bool {
    #[cfg(target_arch = "x86_64")]
    if std::arch::is_x86_feature_detected!("avx2") {
        unsafe { x86::acc_tile_u4(acc, a, bp, row0, ilen, k, n) };
        return true;
    }
    #[cfg(target_arch = "aarch64")]
    {
        unsafe { neon::acc_tile_u4(acc, a, bp, row0, ilen, k, n) };
        return true;
    }
    #[cfg(not(target_arch = "aarch64"))]
    {
        let _ = (acc, a, bp, row0, ilen, k, n);
        false
    }
}

#[cfg(target_arch = "x86_64")]
mod x86 {
    use super::TILE_K;
    use std::arch::x86_64::*;

    /// 4-wide f64 update of one accumulator row: the scalar fold
    /// `acc[j] += a0·b0[j]; acc[j] += a1·b1[j]; …` — four *sequential*
    /// mul-then-add steps per lane (no FMA, no grouped 4-term sum), so
    /// every column rounds exactly as the scalar tile does and the fold
    /// stays a strict k-ascending sequence (the slice-invariance
    /// contract in `ops.rs`).
    #[target_feature(enable = "avx2")]
    unsafe fn f64_j4(
        acc: &mut [f64],
        a0: f64,
        a1: f64,
        a2: f64,
        a3: f64,
        b0: &[f32],
        b1: &[f32],
        b2: &[f32],
        b3: &[f32],
        n: usize,
    ) {
        let va0 = _mm256_set1_pd(a0);
        let va1 = _mm256_set1_pd(a1);
        let va2 = _mm256_set1_pd(a2);
        let va3 = _mm256_set1_pd(a3);
        let mut j = 0;
        while j + 4 <= n {
            let b0v = _mm256_cvtps_pd(_mm_loadu_ps(b0.as_ptr().add(j)));
            let b1v = _mm256_cvtps_pd(_mm_loadu_ps(b1.as_ptr().add(j)));
            let b2v = _mm256_cvtps_pd(_mm_loadu_ps(b2.as_ptr().add(j)));
            let b3v = _mm256_cvtps_pd(_mm_loadu_ps(b3.as_ptr().add(j)));
            let mut av = _mm256_loadu_pd(acc.as_ptr().add(j));
            av = _mm256_add_pd(av, _mm256_mul_pd(va0, b0v));
            av = _mm256_add_pd(av, _mm256_mul_pd(va1, b1v));
            av = _mm256_add_pd(av, _mm256_mul_pd(va2, b2v));
            av = _mm256_add_pd(av, _mm256_mul_pd(va3, b3v));
            _mm256_storeu_pd(acc.as_mut_ptr().add(j), av);
            j += 4;
        }
        while j < n {
            acc[j] += a0 * b0[j] as f64;
            acc[j] += a1 * b1[j] as f64;
            acc[j] += a2 * b2[j] as f64;
            acc[j] += a3 * b3[j] as f64;
            j += 1;
        }
    }

    /// Single-k f64 update: `acc[j] += av · brow[j]`.
    #[target_feature(enable = "avx2")]
    unsafe fn f64_j1(acc: &mut [f64], av: f64, brow: &[f32], n: usize) {
        let vav = _mm256_set1_pd(av);
        let mut j = 0;
        while j + 4 <= n {
            let bv = _mm256_cvtps_pd(_mm_loadu_ps(brow.as_ptr().add(j)));
            let t = _mm256_mul_pd(vav, bv);
            let av4 = _mm256_loadu_pd(acc.as_ptr().add(j));
            _mm256_storeu_pd(acc.as_mut_ptr().add(j), _mm256_add_pd(av4, t));
            j += 4;
        }
        while j < n {
            acc[j] += av * brow[j] as f64;
            j += 1;
        }
    }

    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn acc_tile_f32(
        acc: &mut [f64],
        a: &[f32],
        b: &[f32],
        row0: usize,
        ilen: usize,
        k: usize,
        n: usize,
    ) {
        for kb in (0..k).step_by(TILE_K) {
            let klen = TILE_K.min(k - kb);
            for ii in 0..ilen {
                let arow = &a[(row0 + ii) * k + kb..][..klen];
                let accrow = &mut acc[ii * n..(ii + 1) * n];
                let mut kk = 0;
                while kk + 4 <= klen {
                    let a0 = arow[kk] as f64;
                    let a1 = arow[kk + 1] as f64;
                    let a2 = arow[kk + 2] as f64;
                    let a3 = arow[kk + 3] as f64;
                    if a0 != 0.0 || a1 != 0.0 || a2 != 0.0 || a3 != 0.0 {
                        let b0 = &b[(kb + kk) * n..][..n];
                        let b1 = &b[(kb + kk + 1) * n..][..n];
                        let b2 = &b[(kb + kk + 2) * n..][..n];
                        let b3 = &b[(kb + kk + 3) * n..][..n];
                        f64_j4(accrow, a0, a1, a2, a3, b0, b1, b2, b3, n);
                    }
                    kk += 4;
                }
                while kk < klen {
                    let av = arow[kk] as f64;
                    if av != 0.0 {
                        f64_j1(accrow, av, &b[(kb + kk) * n..][..n], n);
                    }
                    kk += 1;
                }
            }
        }
    }

    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn acc_tn_f32(
        acc: &mut [f64],
        a: &[f32],
        b: &[f32],
        k0: usize,
        klen: usize,
        m: usize,
        k: usize,
        n: usize,
    ) {
        for i in 0..m {
            let arow = &a[i * k + k0..][..klen];
            let brow = &b[i * n..(i + 1) * n];
            for (kk, &av) in arow.iter().enumerate() {
                if av == 0.0 {
                    continue;
                }
                f64_j1(&mut acc[kk * n..(kk + 1) * n], av as f64, brow, n);
            }
        }
    }

    /// 8-wide i32 update of one accumulator row (exact — lane order is
    /// irrelevant for integer sums under the overflow gate).
    #[target_feature(enable = "avx2")]
    unsafe fn i32_j8(
        acc: &mut [i32],
        a0: i32,
        a1: i32,
        a2: i32,
        a3: i32,
        b0: &[i8],
        b1: &[i8],
        b2: &[i8],
        b3: &[i8],
        n: usize,
    ) {
        let va0 = _mm256_set1_epi32(a0);
        let va1 = _mm256_set1_epi32(a1);
        let va2 = _mm256_set1_epi32(a2);
        let va3 = _mm256_set1_epi32(a3);
        let mut j = 0;
        while j + 8 <= n {
            let b0v = _mm256_cvtepi8_epi32(_mm_loadl_epi64(b0.as_ptr().add(j) as *const __m128i));
            let b1v = _mm256_cvtepi8_epi32(_mm_loadl_epi64(b1.as_ptr().add(j) as *const __m128i));
            let b2v = _mm256_cvtepi8_epi32(_mm_loadl_epi64(b2.as_ptr().add(j) as *const __m128i));
            let b3v = _mm256_cvtepi8_epi32(_mm_loadl_epi64(b3.as_ptr().add(j) as *const __m128i));
            let t = _mm256_add_epi32(
                _mm256_add_epi32(
                    _mm256_add_epi32(_mm256_mullo_epi32(va0, b0v), _mm256_mullo_epi32(va1, b1v)),
                    _mm256_mullo_epi32(va2, b2v),
                ),
                _mm256_mullo_epi32(va3, b3v),
            );
            let av = _mm256_loadu_si256(acc.as_ptr().add(j) as *const __m256i);
            _mm256_storeu_si256(acc.as_mut_ptr().add(j) as *mut __m256i, _mm256_add_epi32(av, t));
            j += 8;
        }
        while j < n {
            acc[j] +=
                a0 * b0[j] as i32 + a1 * b1[j] as i32 + a2 * b2[j] as i32 + a3 * b3[j] as i32;
            j += 1;
        }
    }

    #[target_feature(enable = "avx2")]
    unsafe fn i32_j1(acc: &mut [i32], av: i32, brow: &[i8], n: usize) {
        let vav = _mm256_set1_epi32(av);
        let mut j = 0;
        while j + 8 <= n {
            let bv = _mm256_cvtepi8_epi32(_mm_loadl_epi64(brow.as_ptr().add(j) as *const __m128i));
            let t = _mm256_mullo_epi32(vav, bv);
            let a8 = _mm256_loadu_si256(acc.as_ptr().add(j) as *const __m256i);
            _mm256_storeu_si256(acc.as_mut_ptr().add(j) as *mut __m256i, _mm256_add_epi32(a8, t));
            j += 8;
        }
        while j < n {
            acc[j] += av * brow[j] as i32;
            j += 1;
        }
    }

    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn acc_tile_i8(
        acc: &mut [i32],
        a: &[i8],
        b: &[i8],
        row0: usize,
        ilen: usize,
        k: usize,
        n: usize,
    ) {
        for kb in (0..k).step_by(TILE_K) {
            let klen = TILE_K.min(k - kb);
            for ii in 0..ilen {
                let arow = &a[(row0 + ii) * k + kb..][..klen];
                let accrow = &mut acc[ii * n..(ii + 1) * n];
                let mut kk = 0;
                while kk + 4 <= klen {
                    let a0 = arow[kk] as i32;
                    let a1 = arow[kk + 1] as i32;
                    let a2 = arow[kk + 2] as i32;
                    let a3 = arow[kk + 3] as i32;
                    if a0 != 0 || a1 != 0 || a2 != 0 || a3 != 0 {
                        let b0 = &b[(kb + kk) * n..][..n];
                        let b1 = &b[(kb + kk + 1) * n..][..n];
                        let b2 = &b[(kb + kk + 2) * n..][..n];
                        let b3 = &b[(kb + kk + 3) * n..][..n];
                        i32_j8(accrow, a0, a1, a2, a3, b0, b1, b2, b3, n);
                    }
                    kk += 4;
                }
                while kk < klen {
                    let av = arow[kk] as i32;
                    if av != 0 {
                        i32_j1(accrow, av, &b[(kb + kk) * n..][..n], n);
                    }
                    kk += 1;
                }
            }
        }
    }

    /// 4-wide f64 update against i8 weights: widen 4 levels to f64
    /// (exact), then the same mul/left-associated-add order per lane.
    #[target_feature(enable = "avx2")]
    unsafe fn f64_i8_j4(
        acc: &mut [f64],
        a0: f64,
        a1: f64,
        a2: f64,
        a3: f64,
        b0: &[i8],
        b1: &[i8],
        b2: &[i8],
        b3: &[i8],
        n: usize,
    ) {
        #[target_feature(enable = "avx2")]
        unsafe fn widen4(p: *const i8) -> __m256d {
            let raw = (p as *const i32).read_unaligned();
            _mm256_cvtepi32_pd(_mm_cvtepi8_epi32(_mm_cvtsi32_si128(raw)))
        }
        let va0 = _mm256_set1_pd(a0);
        let va1 = _mm256_set1_pd(a1);
        let va2 = _mm256_set1_pd(a2);
        let va3 = _mm256_set1_pd(a3);
        let mut j = 0;
        while j + 4 <= n {
            let b0v = widen4(b0.as_ptr().add(j));
            let b1v = widen4(b1.as_ptr().add(j));
            let b2v = widen4(b2.as_ptr().add(j));
            let b3v = widen4(b3.as_ptr().add(j));
            let t = _mm256_add_pd(
                _mm256_add_pd(
                    _mm256_add_pd(_mm256_mul_pd(va0, b0v), _mm256_mul_pd(va1, b1v)),
                    _mm256_mul_pd(va2, b2v),
                ),
                _mm256_mul_pd(va3, b3v),
            );
            let av = _mm256_loadu_pd(acc.as_ptr().add(j));
            _mm256_storeu_pd(acc.as_mut_ptr().add(j), _mm256_add_pd(av, t));
            j += 4;
        }
        while j < n {
            acc[j] += a0 * b0[j] as f64 + a1 * b1[j] as f64 + a2 * b2[j] as f64 + a3 * b3[j] as f64;
            j += 1;
        }
    }

    #[target_feature(enable = "avx2")]
    unsafe fn f64_i8_j1(acc: &mut [f64], av: f64, brow: &[i8], n: usize) {
        let vav = _mm256_set1_pd(av);
        let mut j = 0;
        while j + 4 <= n {
            let raw = (brow.as_ptr().add(j) as *const i32).read_unaligned();
            let bv = _mm256_cvtepi32_pd(_mm_cvtepi8_epi32(_mm_cvtsi32_si128(raw)));
            let t = _mm256_mul_pd(vav, bv);
            let a4 = _mm256_loadu_pd(acc.as_ptr().add(j));
            _mm256_storeu_pd(acc.as_mut_ptr().add(j), _mm256_add_pd(a4, t));
            j += 4;
        }
        while j < n {
            acc[j] += av * brow[j] as f64;
            j += 1;
        }
    }

    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn acc_tile_f32i8(
        acc: &mut [f64],
        a: &[f32],
        b: &[i8],
        row0: usize,
        ilen: usize,
        k: usize,
        n: usize,
    ) {
        for kb in (0..k).step_by(TILE_K) {
            let klen = TILE_K.min(k - kb);
            for ii in 0..ilen {
                let arow = &a[(row0 + ii) * k + kb..][..klen];
                let accrow = &mut acc[ii * n..(ii + 1) * n];
                let mut kk = 0;
                while kk + 4 <= klen {
                    let a0 = arow[kk] as f64;
                    let a1 = arow[kk + 1] as f64;
                    let a2 = arow[kk + 2] as f64;
                    let a3 = arow[kk + 3] as f64;
                    if a0 != 0.0 || a1 != 0.0 || a2 != 0.0 || a3 != 0.0 {
                        let b0 = &b[(kb + kk) * n..][..n];
                        let b1 = &b[(kb + kk + 1) * n..][..n];
                        let b2 = &b[(kb + kk + 2) * n..][..n];
                        let b3 = &b[(kb + kk + 3) * n..][..n];
                        f64_i8_j4(accrow, a0, a1, a2, a3, b0, b1, b2, b3, n);
                    }
                    kk += 4;
                }
                while kk < klen {
                    let av = arow[kk] as f64;
                    if av != 0.0 {
                        f64_i8_j1(accrow, av, &b[(kb + kk) * n..][..n], n);
                    }
                    kk += 1;
                }
            }
        }
    }

    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn acc_tile_u4(
        acc: &mut [i32],
        a: &[i8],
        bp: &[u8],
        row0: usize,
        ilen: usize,
        k: usize,
        n: usize,
    ) {
        let nb = n.div_ceil(2);
        let full = n / 2;
        let mask = _mm_set1_epi8(0x0F);
        let bias = _mm_set1_epi8(8);
        for kb in (0..k).step_by(TILE_K) {
            let klen = TILE_K.min(k - kb);
            for ii in 0..ilen {
                let arow = &a[(row0 + ii) * k + kb..][..klen];
                let accrow = &mut acc[ii * n..(ii + 1) * n];
                for (kk, &araw) in arow.iter().enumerate() {
                    let av = araw as i32;
                    if av == 0 {
                        continue;
                    }
                    let brow = &bp[(kb + kk) * nb..][..nb];
                    let vav = _mm256_set1_epi32(av);
                    let mut jb = 0;
                    // 8 packed bytes -> 16 columns per step
                    while 2 * jb + 16 <= n {
                        let vb = _mm_loadl_epi64(brow.as_ptr().add(jb) as *const __m128i);
                        let lo = _mm_and_si128(vb, mask);
                        let hi = _mm_and_si128(_mm_srli_epi16::<4>(vb), mask);
                        // interleave restores column order: lo0 hi0 lo1 hi1 ...
                        let nib = _mm_unpacklo_epi8(lo, hi);
                        // sign-extend 4-bit two's complement: (x ^ 8) - 8
                        let s = _mm_sub_epi8(_mm_xor_si128(nib, bias), bias);
                        let w0 = _mm256_cvtepi8_epi32(s);
                        let w1 = _mm256_cvtepi8_epi32(_mm_srli_si128::<8>(s));
                        let j = 2 * jb;
                        let a0 = _mm256_loadu_si256(accrow.as_ptr().add(j) as *const __m256i);
                        _mm256_storeu_si256(
                            accrow.as_mut_ptr().add(j) as *mut __m256i,
                            _mm256_add_epi32(a0, _mm256_mullo_epi32(vav, w0)),
                        );
                        let a1 = _mm256_loadu_si256(accrow.as_ptr().add(j + 8) as *const __m256i);
                        _mm256_storeu_si256(
                            accrow.as_mut_ptr().add(j + 8) as *mut __m256i,
                            _mm256_add_epi32(a1, _mm256_mullo_epi32(vav, w1)),
                        );
                        jb += 8;
                    }
                    while jb < full {
                        let byte = brow[jb];
                        accrow[2 * jb] += av * ((((byte & 0x0F) ^ 8) as i32) - 8);
                        accrow[2 * jb + 1] += av * ((((byte >> 4) ^ 8) as i32) - 8);
                        jb += 1;
                    }
                    if n % 2 == 1 {
                        accrow[n - 1] += av * ((((brow[nb - 1] & 0x0F) ^ 8) as i32) - 8);
                    }
                }
            }
        }
    }
}

#[cfg(target_arch = "aarch64")]
mod neon {
    use super::TILE_K;
    use std::arch::aarch64::*;

    /// 2-wide f64 update: the same four *sequential* mul-then-add steps
    /// per lane as the scalar tile (no FMA, no grouped 4-term sum), so
    /// the per-column fold stays strictly k-ascending.
    unsafe fn f64_j4(
        acc: &mut [f64],
        a0: f64,
        a1: f64,
        a2: f64,
        a3: f64,
        b0: &[f32],
        b1: &[f32],
        b2: &[f32],
        b3: &[f32],
        n: usize,
    ) {
        let va0 = vdupq_n_f64(a0);
        let va1 = vdupq_n_f64(a1);
        let va2 = vdupq_n_f64(a2);
        let va3 = vdupq_n_f64(a3);
        let mut j = 0;
        while j + 2 <= n {
            let b0v = vcvt_f64_f32(vld1_f32(b0.as_ptr().add(j)));
            let b1v = vcvt_f64_f32(vld1_f32(b1.as_ptr().add(j)));
            let b2v = vcvt_f64_f32(vld1_f32(b2.as_ptr().add(j)));
            let b3v = vcvt_f64_f32(vld1_f32(b3.as_ptr().add(j)));
            let mut av = vld1q_f64(acc.as_ptr().add(j));
            av = vaddq_f64(av, vmulq_f64(va0, b0v));
            av = vaddq_f64(av, vmulq_f64(va1, b1v));
            av = vaddq_f64(av, vmulq_f64(va2, b2v));
            av = vaddq_f64(av, vmulq_f64(va3, b3v));
            vst1q_f64(acc.as_mut_ptr().add(j), av);
            j += 2;
        }
        while j < n {
            acc[j] += a0 * b0[j] as f64;
            acc[j] += a1 * b1[j] as f64;
            acc[j] += a2 * b2[j] as f64;
            acc[j] += a3 * b3[j] as f64;
            j += 1;
        }
    }

    unsafe fn f64_j1(acc: &mut [f64], av: f64, brow: &[f32], n: usize) {
        let vav = vdupq_n_f64(av);
        let mut j = 0;
        while j + 2 <= n {
            let bv = vcvt_f64_f32(vld1_f32(brow.as_ptr().add(j)));
            let t = vmulq_f64(vav, bv);
            let a2 = vld1q_f64(acc.as_ptr().add(j));
            vst1q_f64(acc.as_mut_ptr().add(j), vaddq_f64(a2, t));
            j += 2;
        }
        while j < n {
            acc[j] += av * brow[j] as f64;
            j += 1;
        }
    }

    pub(super) unsafe fn acc_tile_f32(
        acc: &mut [f64],
        a: &[f32],
        b: &[f32],
        row0: usize,
        ilen: usize,
        k: usize,
        n: usize,
    ) {
        for kb in (0..k).step_by(TILE_K) {
            let klen = TILE_K.min(k - kb);
            for ii in 0..ilen {
                let arow = &a[(row0 + ii) * k + kb..][..klen];
                let accrow = &mut acc[ii * n..(ii + 1) * n];
                let mut kk = 0;
                while kk + 4 <= klen {
                    let a0 = arow[kk] as f64;
                    let a1 = arow[kk + 1] as f64;
                    let a2 = arow[kk + 2] as f64;
                    let a3 = arow[kk + 3] as f64;
                    if a0 != 0.0 || a1 != 0.0 || a2 != 0.0 || a3 != 0.0 {
                        let b0 = &b[(kb + kk) * n..][..n];
                        let b1 = &b[(kb + kk + 1) * n..][..n];
                        let b2 = &b[(kb + kk + 2) * n..][..n];
                        let b3 = &b[(kb + kk + 3) * n..][..n];
                        f64_j4(accrow, a0, a1, a2, a3, b0, b1, b2, b3, n);
                    }
                    kk += 4;
                }
                while kk < klen {
                    let av = arow[kk] as f64;
                    if av != 0.0 {
                        f64_j1(accrow, av, &b[(kb + kk) * n..][..n], n);
                    }
                    kk += 1;
                }
            }
        }
    }

    pub(super) unsafe fn acc_tn_f32(
        acc: &mut [f64],
        a: &[f32],
        b: &[f32],
        k0: usize,
        klen: usize,
        m: usize,
        k: usize,
        n: usize,
    ) {
        for i in 0..m {
            let arow = &a[i * k + k0..][..klen];
            let brow = &b[i * n..(i + 1) * n];
            for (kk, &av) in arow.iter().enumerate() {
                if av == 0.0 {
                    continue;
                }
                f64_j1(&mut acc[kk * n..(kk + 1) * n], av as f64, brow, n);
            }
        }
    }

    /// Widen 8 i8 levels to two int32x4 and accumulate `av · level`.
    unsafe fn i32_j8(acc: &mut [i32], av: i32, brow: &[i8], n: usize) {
        let vav = vdupq_n_s32(av);
        let mut j = 0;
        while j + 8 <= n {
            let b8 = vld1_s8(brow.as_ptr().add(j));
            let w = vmovl_s8(b8);
            let w0 = vmovl_s16(vget_low_s16(w));
            let w1 = vmovl_s16(vget_high_s16(w));
            let a0 = vld1q_s32(acc.as_ptr().add(j));
            vst1q_s32(acc.as_mut_ptr().add(j), vaddq_s32(a0, vmulq_s32(vav, w0)));
            let a1 = vld1q_s32(acc.as_ptr().add(j + 4));
            vst1q_s32(acc.as_mut_ptr().add(j + 4), vaddq_s32(a1, vmulq_s32(vav, w1)));
            j += 8;
        }
        while j < n {
            acc[j] += av * brow[j] as i32;
            j += 1;
        }
    }

    pub(super) unsafe fn acc_tile_i8(
        acc: &mut [i32],
        a: &[i8],
        b: &[i8],
        row0: usize,
        ilen: usize,
        k: usize,
        n: usize,
    ) {
        for kb in (0..k).step_by(TILE_K) {
            let klen = TILE_K.min(k - kb);
            for ii in 0..ilen {
                let arow = &a[(row0 + ii) * k + kb..][..klen];
                let accrow = &mut acc[ii * n..(ii + 1) * n];
                for (kk, &araw) in arow.iter().enumerate() {
                    let av = araw as i32;
                    if av != 0 {
                        i32_j8(accrow, av, &b[(kb + kk) * n..][..n], n);
                    }
                }
            }
        }
    }

    pub(super) unsafe fn acc_tile_u4(
        acc: &mut [i32],
        a: &[i8],
        bp: &[u8],
        row0: usize,
        ilen: usize,
        k: usize,
        n: usize,
    ) {
        let nb = n.div_ceil(2);
        let full = n / 2;
        let mask = vdup_n_u8(0x0F);
        let bias = vdup_n_s8(8);
        for kb in (0..k).step_by(TILE_K) {
            let klen = TILE_K.min(k - kb);
            for ii in 0..ilen {
                let arow = &a[(row0 + ii) * k + kb..][..klen];
                let accrow = &mut acc[ii * n..(ii + 1) * n];
                for (kk, &araw) in arow.iter().enumerate() {
                    let av = araw as i32;
                    if av == 0 {
                        continue;
                    }
                    let brow = &bp[(kb + kk) * nb..][..nb];
                    let vav = vdupq_n_s32(av);
                    let mut jb = 0;
                    // 8 packed bytes -> 16 columns per step
                    while 2 * jb + 16 <= n {
                        let vb = vld1_u8(brow.as_ptr().add(jb));
                        let lo = vand_u8(vb, mask);
                        let hi = vand_u8(vshr_n_u8::<4>(vb), mask);
                        // interleave restores column order, then (x^8)-8
                        // sign-extends the 4-bit two's-complement nibbles
                        let z0 = vzip1_u8(lo, hi);
                        let z1 = vzip2_u8(lo, hi);
                        let s0 = vsub_s8(vreinterpret_s8_u8(veor_u8(z0, vreinterpret_u8_s8(bias))), bias);
                        let s1 = vsub_s8(vreinterpret_s8_u8(veor_u8(z1, vreinterpret_u8_s8(bias))), bias);
                        let j = 2 * jb;
                        let w0 = vmovl_s8(s0);
                        let w1 = vmovl_s8(s1);
                        let c0 = vmovl_s16(vget_low_s16(w0));
                        let c1 = vmovl_s16(vget_high_s16(w0));
                        let c2 = vmovl_s16(vget_low_s16(w1));
                        let c3 = vmovl_s16(vget_high_s16(w1));
                        let a0 = vld1q_s32(accrow.as_ptr().add(j));
                        vst1q_s32(accrow.as_mut_ptr().add(j), vaddq_s32(a0, vmulq_s32(vav, c0)));
                        let a1 = vld1q_s32(accrow.as_ptr().add(j + 4));
                        vst1q_s32(accrow.as_mut_ptr().add(j + 4), vaddq_s32(a1, vmulq_s32(vav, c1)));
                        let a2 = vld1q_s32(accrow.as_ptr().add(j + 8));
                        vst1q_s32(accrow.as_mut_ptr().add(j + 8), vaddq_s32(a2, vmulq_s32(vav, c2)));
                        let a3 = vld1q_s32(accrow.as_ptr().add(j + 12));
                        vst1q_s32(accrow.as_mut_ptr().add(j + 12), vaddq_s32(a3, vmulq_s32(vav, c3)));
                        jb += 8;
                    }
                    while jb < full {
                        let byte = brow[jb];
                        accrow[2 * jb] += av * ((((byte & 0x0F) ^ 8) as i32) - 8);
                        accrow[2 * jb + 1] += av * ((((byte >> 4) ^ 8) as i32) - 8);
                        jb += 1;
                    }
                    if n % 2 == 1 {
                        accrow[n - 1] += av * ((((brow[nb - 1] & 0x0F) ^ 8) as i32) - 8);
                    }
                }
            }
        }
    }
}
