//! Shared tiling + thread-budget configuration for every GEMM kernel
//! family (`ops.rs` f32, `iops.rs` i8, `u4.rs` nibble-packed, and the
//! SIMD dispatch layer in `simd.rs`). One source of truth: a tile or
//! lane retune here retunes every kernel at once, and all of them honor
//! one process-wide worker budget.
//!
//! The budget resolves, in priority order, from `set_threads` (the CLI
//! `--threads` plumbing), the `GETA_THREADS` environment variable, then
//! `available_parallelism`.
//!
//! Determinism contract: every output element is produced by exactly one
//! worker with an accumulation order fixed by (shape, constants) alone,
//! so kernel results are **bitwise identical for every thread count** —
//! the invariant the threaded-determinism e2e tests pin.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Output-row block: a panel of `TILE_I` rows shares one cache-hot block
/// of `b` rows. Shared by the f32, i8 and u4 kernels, which all promise
/// the same per-row accumulation order — a tune here retunes them all.
pub(crate) const TILE_I: usize = 16;
/// k-axis block: the reduction is walked in `TILE_K` chunks so the `b`
/// panel stays resident across a block of output rows. Per-row
/// accumulation order is a function of (k, `TILE_K`) only, which is what
/// makes results independent of tile/thread partitioning.
pub(crate) const TILE_K: usize = 256;

static THREADS: AtomicUsize = AtomicUsize::new(0);

/// Override the worker-thread budget (CLI `--threads`). Takes precedence
/// over `GETA_THREADS` and the machine's parallelism.
pub fn set_threads(n: usize) {
    THREADS.store(n.max(1), Ordering::Relaxed);
}

/// Resolve the worker-thread budget (see the module notes above). The
/// environment is consulted once; later calls return the cached value.
pub fn configured_threads() -> usize {
    let t = THREADS.load(Ordering::Relaxed);
    if t != 0 {
        return t;
    }
    let n = std::env::var("GETA_THREADS")
        .ok()
        .and_then(|s| s.parse::<usize>().ok())
        .filter(|&n| n >= 1)
        .unwrap_or_else(|| std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1));
    THREADS.store(n, Ordering::Relaxed);
    n
}

/// Serializes the #[test]s that mutate the process-global thread budget:
/// cargo runs tests concurrently in one binary, so without one shared
/// lock a concurrent `set_threads()` could retarget a sibling's labeled
/// runs. Shared by the `ops`, `iops` and `u4` test modules.
#[cfg(test)]
pub(crate) static THREAD_TEST_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

thread_local! {
    static SERIAL: std::cell::Cell<bool> = const { std::cell::Cell::new(false) };
}

/// Run `f` with the tiled kernels pinned to one thread on the calling
/// thread. Callers that already shard work across their own workers
/// (micro-batch sharding in `deploy::GetaEngine::infer`) wrap each worker
/// body in this so nested parallelism cannot oversubscribe the machine.
pub fn serial_scope<R>(f: impl FnOnce() -> R) -> R {
    SERIAL.with(|s| {
        let prev = s.replace(true);
        let out = f();
        s.set(prev);
        out
    })
}

/// Worker count for a kernel doing `work` multiply-adds over `rows`
/// partitionable output rows: 1 inside [`serial_scope`] or when the job is
/// too small to amortize a spawn, else the configured budget. Shared by
/// the f32 (`ops.rs`), integer (`iops.rs`) and nibble-packed (`u4.rs`)
/// kernels so every half of the executor honors one thread budget.
pub(crate) fn kernel_threads(work: usize, rows: usize) -> usize {
    const MIN_WORK_PER_THREAD: usize = 1 << 16;
    if work < 2 * MIN_WORK_PER_THREAD || SERIAL.with(|s| s.get()) {
        return 1;
    }
    configured_threads().min(work / MIN_WORK_PER_THREAD).min(rows).max(1)
}
