//! Nibble-packed 4-bit weight panels and their GEMM kernels.
//!
//! `iops.rs` widens every ≤8-bit site to one i8 per level, so a 4-bit
//! GETA model still moves i8 bytes. This module is the true sub-byte
//! path: a [`U4Weight`] stores two levels per byte (`[k, ceil(n/2)]`
//! row-major panels — the same `[k, n]` orientation the i8 and f32
//! kernels walk), and the GEMM microkernels unpack nibbles in-register,
//! so a ≤4-bit site is served moving **half the bytes** of the i8 path.
//!
//! Packing convention (matches the `.geta` container's LSB-first
//! `pack_levels`): the **low** nibble of byte `jb` is column `2·jb`, the
//! **high** nibble is column `2·jb + 1`; odd `n` leaves the last high
//! nibble zero. Levels are 4-bit two's complement, `|l| ≤ 7` (the b=4
//! fake-quant bound); sign-extension is `(x ^ 8) - 8`.
//!
//! Determinism mirrors `iops.rs`: the i8×u4 kernel accumulates in i32
//! (associative — bitwise identical for every thread count and for the
//! SIMD bodies by construction, under the [`super::i8_gemm_fits_i32`]
//! gate); the mixed f32×u4 kernel accumulates in f64 with a per-row
//! order that is a function of `(k, TILE_K)` only.

use super::tile::{kernel_threads, TILE_I, TILE_K};

/// Sign-extend a 4-bit two's-complement nibble (low 4 bits of `x`).
#[inline]
pub fn nibble_i32(x: u8) -> i32 {
    (((x & 0x0F) ^ 8) as i32) - 8
}

/// Pack i8 levels (each in `[-8, 7]`) two per byte, LSB-first: even
/// index -> low nibble, odd index -> high nibble. Odd-length tails leave
/// the last high nibble zero. Inverse of [`unpack_nibbles`].
pub fn pack_nibbles(levels: &[i8]) -> Vec<u8> {
    let mut out = vec![0u8; levels.len().div_ceil(2)];
    for (j, &l) in levels.iter().enumerate() {
        debug_assert!((-8..=7).contains(&l), "level {l} outside 4-bit range");
        let nib = (l as u8) & 0x0F;
        if j % 2 == 0 {
            out[j / 2] |= nib;
        } else {
            out[j / 2] |= nib << 4;
        }
    }
    out
}

/// Unpack `n` levels from LSB-first nibble pairs (see [`pack_nibbles`]).
pub fn unpack_nibbles(bytes: &[u8], n: usize) -> Vec<i8> {
    assert!(bytes.len() >= n.div_ceil(2), "packed buffer too short for {n} levels");
    let mut out = Vec::with_capacity(n);
    for j in 0..n {
        let byte = bytes[j / 2];
        let nib = if j % 2 == 0 { byte } else { byte >> 4 };
        out.push(nibble_i32(nib) as i8);
    }
    out
}

/// One weight tensor held as resident nibble-packed 4-bit levels — the
/// sub-byte counterpart of [`super::IntWeight`], same `[k, n]` panel
/// orientation (linear `[din, dout]`; conv HWIO flattened to
/// `[k²·cin, cout]`) at half the bytes.
#[derive(Debug, Clone)]
pub struct U4Weight {
    /// Packed levels, `[k, ceil(n/2)]` row-major, two columns per byte.
    pub packed: Vec<u8>,
    /// Contraction length (weight rows).
    pub k: usize,
    /// Output channels (weight cols).
    pub n: usize,
    /// Per-output-channel dequantization scale (the site's step `d_w`).
    pub scale: Vec<f32>,
    /// `max |level|`, for the i32 overflow gate.
    pub max_abs: i32,
}

impl U4Weight {
    /// Build from unpacked container levels, or `None` when any level
    /// falls outside the 4-bit range `|l| ≤ 7` (a site trained past 4
    /// bits — the caller falls back to the i8 or f32 path).
    pub fn from_levels(levels: &[i32], n: usize, d: f32) -> Option<U4Weight> {
        if n == 0 || levels.len() % n != 0 {
            return None;
        }
        let mut max_abs = 0i32;
        for &l in levels {
            if !(-7..=7).contains(&l) {
                return None;
            }
            max_abs = max_abs.max(l.abs());
        }
        let k = levels.len() / n;
        let nb = n.div_ceil(2);
        let mut packed = vec![0u8; k * nb];
        for r in 0..k {
            let row = &levels[r * n..(r + 1) * n];
            let prow = &mut packed[r * nb..(r + 1) * nb];
            for (j, &l) in row.iter().enumerate() {
                let nib = (l as u8) & 0x0F;
                if j % 2 == 0 {
                    prow[j / 2] |= nib;
                } else {
                    prow[j / 2] |= nib << 4;
                }
            }
        }
        Some(U4Weight {
            packed,
            k,
            n,
            scale: vec![d; n],
            max_abs,
        })
    }

    /// Level at `(row, col)` — the defensive/reference accessor; the
    /// kernels never call this per element.
    #[inline]
    pub fn level(&self, r: usize, j: usize) -> i32 {
        let nb = self.n.div_ceil(2);
        let byte = self.packed[r * nb + j / 2];
        nibble_i32(if j % 2 == 0 { byte } else { byte >> 4 })
    }

    /// Unpack the whole panel to one i8 per level, `[k, n]` row-major —
    /// the bridge to the i8 reference kernels in differential tests.
    pub fn unpack_levels(&self) -> Vec<i8> {
        let nb = self.n.div_ceil(2);
        let mut out = Vec::with_capacity(self.k * self.n);
        for r in 0..self.k {
            out.extend_from_slice(&unpack_nibbles(&self.packed[r * nb..(r + 1) * nb], self.n));
        }
        out
    }

    /// Resident bytes of the packed panel (the bandwidth the GEMM moves).
    pub fn packed_bytes(&self) -> usize {
        self.packed.len()
    }
}

// ---------------------------------------------------------- i8 × u4 GEMM

/// Accumulate rows `row0..row0+ilen` of `a @ unpack(w)` into the i32
/// tile `acc` (`ilen × n`, pre-zeroed), unpacking nibbles on the fly.
/// Exact i32 accumulation — lane/loop order is irrelevant under the
/// overflow gate, so the SIMD body needs no order discipline.
fn acc_tile_u4(acc: &mut [i32], a: &[i8], w: &U4Weight, row0: usize, ilen: usize) {
    #[cfg(feature = "simd")]
    if super::simd::acc_tile_u4(acc, a, &w.packed, row0, ilen, w.k, w.n) {
        return;
    }
    let (k, n) = (w.k, w.n);
    let nb = n.div_ceil(2);
    let full = n / 2;
    for kb in (0..k).step_by(TILE_K) {
        let klen = TILE_K.min(k - kb);
        for ii in 0..ilen {
            let arow = &a[(row0 + ii) * k + kb..][..klen];
            let accrow = &mut acc[ii * n..(ii + 1) * n];
            for (kk, &araw) in arow.iter().enumerate() {
                let av = araw as i32;
                if av == 0 {
                    continue;
                }
                let brow = &w.packed[(kb + kk) * nb..][..nb];
                for jb in 0..full {
                    let byte = brow[jb];
                    accrow[2 * jb] += av * nibble_i32(byte);
                    accrow[2 * jb + 1] += av * nibble_i32(byte >> 4);
                }
                if n % 2 == 1 {
                    accrow[n - 1] += av * nibble_i32(brow[nb - 1]);
                }
            }
        }
    }
}

/// `a[m,k] @ unpack(w)[k,n]` on levels, exact i32 accumulation — tiled +
/// threaded. The caller guarantees no i32 overflow
/// ([`super::i8_gemm_fits_i32`] with `max_w = w.max_abs ≤ 7`).
pub fn matmul_u4(a: &[i8], w: &U4Weight, m: usize) -> Vec<i32> {
    let (k, n) = (w.k, w.n);
    assert_eq!(a.len(), m * k);
    let mut out = vec![0i32; m * n];
    if out.is_empty() || k == 0 {
        return out;
    }
    let nt = kernel_threads(m * k * n, m);
    if nt <= 1 {
        matmul_u4_rows(&mut out, a, w, 0);
        return out;
    }
    let chunk = m.div_ceil(nt);
    let w_ref = &*w;
    std::thread::scope(|sc| {
        for (ti, oc) in out.chunks_mut(chunk * n).enumerate() {
            sc.spawn(move || matmul_u4_rows(oc, a, w_ref, ti * chunk));
        }
    });
    out
}

fn matmul_u4_rows(out: &mut [i32], a: &[i8], w: &U4Weight, i0: usize) {
    let n = w.n;
    let rows = out.len() / n;
    let mut acc = vec![0i32; TILE_I.min(rows.max(1)) * n];
    for ib in (0..rows).step_by(TILE_I) {
        let ilen = TILE_I.min(rows - ib);
        let acc = &mut acc[..ilen * n];
        acc.fill(0);
        acc_tile_u4(acc, a, w, i0 + ib, ilen);
        out[ib * n..(ib + ilen) * n].copy_from_slice(acc);
    }
}

/// The deployment i8×u4 GEMM: exact i32 tiles flushed through the same
/// f64 scale epilogue as [`super::matmul_i8_scaled_into`] —
/// `out[i,j] = f32(acc[i,j] · (alpha · scale[j]) + bias[j])`, `alpha`
/// the activation step `d_a`. The epilogue is the only floating-point
/// rounding of the integer path.
pub fn matmul_i8u4_scaled_into(
    out: &mut [f32],
    a: &[i8],
    w: &U4Weight,
    m: usize,
    alpha: f32,
    bias: Option<&[f32]>,
) {
    let (k, n) = (w.k, w.n);
    assert_eq!(a.len(), m * k);
    assert_eq!(out.len(), m * n);
    assert_eq!(w.scale.len(), n);
    if let Some(bias) = bias {
        assert_eq!(bias.len(), n);
    }
    if out.is_empty() {
        return;
    }
    let comb: Vec<f64> = w.scale.iter().map(|&s| alpha as f64 * s as f64).collect();
    let comb = comb.as_slice();
    let nt = kernel_threads(m * k * n, m);
    if nt <= 1 {
        matmul_i8u4_scaled_rows(out, a, w, 0, comb, bias);
        return;
    }
    let chunk = m.div_ceil(nt);
    let w_ref = &*w;
    std::thread::scope(|sc| {
        for (ti, oc) in out.chunks_mut(chunk * n).enumerate() {
            sc.spawn(move || matmul_i8u4_scaled_rows(oc, a, w_ref, ti * chunk, comb, bias));
        }
    });
}

fn matmul_i8u4_scaled_rows(
    out: &mut [f32],
    a: &[i8],
    w: &U4Weight,
    i0: usize,
    comb: &[f64],
    bias: Option<&[f32]>,
) {
    let n = w.n;
    let rows = out.len() / n;
    let mut acc = vec![0i32; TILE_I.min(rows.max(1)) * n];
    for ib in (0..rows).step_by(TILE_I) {
        let ilen = TILE_I.min(rows - ib);
        let acc = &mut acc[..ilen * n];
        acc.fill(0);
        acc_tile_u4(acc, a, w, i0 + ib, ilen);
        for ii in 0..ilen {
            let orow = &mut out[(ib + ii) * n..(ib + ii + 1) * n];
            match bias {
                Some(bias) => {
                    for j in 0..n {
                        orow[j] = (acc[ii * n + j] as f64 * comb[j] + bias[j] as f64) as f32;
                    }
                }
                None => {
                    for j in 0..n {
                        orow[j] = (acc[ii * n + j] as f64 * comb[j]) as f32;
                    }
                }
            }
        }
    }
}

// ------------------------------------------------------ f32 × u4 GEMM (mixed)

/// Mixed GEMM for weight-only sub-byte quantization: f32 activations
/// against resident nibble-packed levels, f64 accumulation, per-channel
/// scale (+ optional bias) epilogue. Per-row accumulation order is a
/// function of `(k, TILE_K)` only (k ascending within each block), so
/// results are bitwise thread-count-invariant.
pub fn matmul_f32u4_scaled_into(
    out: &mut [f32],
    a: &[f32],
    w: &U4Weight,
    m: usize,
    bias: Option<&[f32]>,
) {
    let (k, n) = (w.k, w.n);
    assert_eq!(a.len(), m * k);
    assert_eq!(out.len(), m * n);
    assert_eq!(w.scale.len(), n);
    if let Some(bias) = bias {
        assert_eq!(bias.len(), n);
    }
    if out.is_empty() {
        return;
    }
    let nt = kernel_threads(m * k * n, m);
    if nt <= 1 {
        matmul_f32u4_rows(out, a, w, 0, bias);
        return;
    }
    let chunk = m.div_ceil(nt);
    let w_ref = &*w;
    std::thread::scope(|sc| {
        for (ti, oc) in out.chunks_mut(chunk * n).enumerate() {
            sc.spawn(move || matmul_f32u4_rows(oc, a, w_ref, ti * chunk, bias));
        }
    });
}

fn matmul_f32u4_rows(out: &mut [f32], a: &[f32], w: &U4Weight, i0: usize, bias: Option<&[f32]>) {
    let (k, n) = (w.k, w.n);
    let nb = n.div_ceil(2);
    let full = n / 2;
    let rows = out.len() / n;
    let mut acc = vec![0.0f64; TILE_I.min(rows.max(1)) * n];
    for ib in (0..rows).step_by(TILE_I) {
        let ilen = TILE_I.min(rows - ib);
        let acc = &mut acc[..ilen * n];
        acc.fill(0.0);
        for kb in (0..k).step_by(TILE_K) {
            let klen = TILE_K.min(k - kb);
            for ii in 0..ilen {
                let arow = &a[(i0 + ib + ii) * k + kb..][..klen];
                let accrow = &mut acc[ii * n..(ii + 1) * n];
                for (kk, &araw) in arow.iter().enumerate() {
                    let av = araw as f64;
                    if av == 0.0 {
                        continue;
                    }
                    let brow = &w.packed[(kb + kk) * nb..][..nb];
                    for jb in 0..full {
                        let byte = brow[jb];
                        accrow[2 * jb] += av * nibble_i32(byte) as f64;
                        accrow[2 * jb + 1] += av * nibble_i32(byte >> 4) as f64;
                    }
                    if n % 2 == 1 {
                        accrow[n - 1] += av * nibble_i32(brow[nb - 1]) as f64;
                    }
                }
            }
        }
        for ii in 0..ilen {
            let orow = &mut out[(ib + ii) * n..(ib + ii + 1) * n];
            match bias {
                Some(bias) => {
                    for j in 0..n {
                        orow[j] =
                            (acc[ii * n + j] * w.scale[j] as f64 + bias[j] as f64) as f32;
                    }
                }
                None => {
                    for j in 0..n {
                        orow[j] = (acc[ii * n + j] * w.scale[j] as f64) as f32;
                    }
                }
            }
        }
    }
}

/// Naive reference: unpack the panel and run the i8 triple loop —
/// compared against the tiled/SIMD kernels by **exact equality** (both
/// sides accumulate in i32).
pub fn matmul_u4_naive(a: &[i8], w: &U4Weight, m: usize) -> Vec<i32> {
    let levels = w.unpack_levels();
    super::iops::matmul_i8_naive(a, &levels, m, w.k, w.n)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::tile::THREAD_TEST_LOCK;
    use crate::tensor::{self};
    use crate::util::prop;
    use crate::util::rng::Rng;

    fn rand_u4_levels(rng: &mut Rng, len: usize, bits: u8) -> Vec<i32> {
        let lmax = (1i32 << (bits - 1)) - 1;
        (0..len).map(|_| rng.below((2 * lmax + 1) as usize) as i32 - lmax).collect()
    }

    #[test]
    fn pack_unpack_hand_values() {
        // [-1, 7] -> low nibble 0xF, high nibble 0x7 -> 0x7F
        assert_eq!(pack_nibbles(&[-1, 7]), vec![0x7F]);
        // odd tail: high nibble of the last byte stays zero
        assert_eq!(pack_nibbles(&[3, -4, 5]), vec![(0x0C << 4) | 0x03, 0x05]);
        assert_eq!(unpack_nibbles(&[0x7F], 2), vec![-1, 7]);
        assert_eq!(unpack_nibbles(&[(0x0C << 4) | 0x03, 0x05], 3), vec![3, -4, 5]);
        for l in -8..=7i32 {
            assert_eq!(nibble_i32((l as u8) & 0x0F), l, "sign-extend {l}");
        }
    }

    #[test]
    fn prop_pack_unpack_roundtrip_bits_2_to_4_with_odd_tails() {
        prop::check(
            60,
            |g| {
                let bits = 2 + g.rng.below(3) as u8; // 2..=4
                let len = 1 + g.rng.below(33); // odd and even tails
                let levels: Vec<i8> =
                    rand_u4_levels(g.rng, len, bits).into_iter().map(|l| l as i8).collect();
                levels
            },
            |levels| {
                let packed = pack_nibbles(levels);
                if packed.len() != levels.len().div_ceil(2) {
                    return Err(format!("packed {} bytes for {} levels", packed.len(), levels.len()));
                }
                let back = unpack_nibbles(&packed, levels.len());
                if &back != levels {
                    return Err(format!("roundtrip {levels:?} -> {back:?}"));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn from_levels_gates_range_and_shape() {
        // 4-bit range ok
        let w = U4Weight::from_levels(&[7, -7, 1, 0, 3, -2], 3, 0.5).unwrap();
        assert_eq!((w.k, w.n), (2, 3));
        assert_eq!(w.max_abs, 7);
        assert_eq!(w.packed_bytes(), 2 * 2); // ceil(3/2) bytes per row
        assert_eq!(w.unpack_levels(), vec![7, -7, 1, 0, 3, -2]);
        assert_eq!(w.level(0, 1), -7);
        // out of range -> None (8 needs 5 bits in this symmetric grid)
        assert!(U4Weight::from_levels(&[8, 0], 2, 0.5).is_none());
        assert!(U4Weight::from_levels(&[-8, 0], 2, 0.5).is_none());
        // ragged / empty -> None
        assert!(U4Weight::from_levels(&[1, 2, 3], 2, 0.5).is_none());
        assert!(U4Weight::from_levels(&[], 0, 0.5).is_none());
    }

    #[test]
    fn prop_tiled_u4_matches_naive_exactly_across_threads() {
        let _guard = THREAD_TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        let prev = tensor::configured_threads();
        for threads in [1usize, 2, 4] {
            tensor::set_threads(threads);
            prop::check(
                8,
                |g| {
                    let m = 16 + g.size(80);
                    let k = 16 + g.size(160);
                    let n = 1 + g.size(70); // odd n exercises the tail nibble
                    let a: Vec<i8> = (0..m * k)
                        .map(|_| (g.rng.below(255) as i32 - 127) as i8)
                        .collect();
                    let levels = rand_u4_levels(g.rng, k * n, 4);
                    (m, k, n, a, levels)
                },
                |(m, _k, n, a, levels)| {
                    let w = U4Weight::from_levels(levels, *n, 1e-3).unwrap();
                    let got = matmul_u4(a, &w, *m);
                    let want = matmul_u4_naive(a, &w, *m);
                    if got != want {
                        return Err(format!("u4 kernel diverged at m={m} n={n}"));
                    }
                    Ok(())
                },
            );
        }
        tensor::set_threads(prev);
    }

    #[test]
    fn u4_scaled_kernels_match_f32_reference() {
        let _guard = THREAD_TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        let prev = tensor::configured_threads();
        tensor::set_threads(2);
        let mut g = Rng::new(0x9e37);
        let (m, k, n) = (24, 33, 17);
        let d = 2e-3f32;
        let levels = rand_u4_levels(&mut g, k * n, 4);
        let w = U4Weight::from_levels(&levels, n, d).unwrap();
        let wf: Vec<f32> = levels.iter().map(|&l| l as f32 * d).collect();
        let bias: Vec<f32> = (0..n).map(|j| (j as f32 - 4.0) * 0.01).collect();
        // exact path: i8 activations
        let da = 3e-3f32;
        let la: Vec<i8> = (0..m * k).map(|_| (g.below(255) as i32 - 127) as i8).collect();
        let af: Vec<f32> = la.iter().map(|&l| l as f32 * da).collect();
        let mut got = vec![0.0f32; m * n];
        matmul_i8u4_scaled_into(&mut got, &la, &w, m, da, Some(&bias));
        let mut want = tensor::ops::matmul(&af, &wf, m, k, n);
        for i in 0..m {
            for j in 0..n {
                want[i * n + j] += bias[j];
            }
        }
        for i in 0..want.len() {
            assert!(
                (got[i] - want[i]).abs() <= 1e-4 * (1.0 + want[i].abs()),
                "i8u4[{i}]: {} vs {}",
                got[i],
                want[i]
            );
        }
        // mixed path: f32 activations straight through
        let mut got2 = vec![0.0f32; m * n];
        matmul_f32u4_scaled_into(&mut got2, &af, &w, m, Some(&bias));
        for i in 0..want.len() {
            assert!(
                (got2[i] - want[i]).abs() <= 1e-4 * (1.0 + want[i].abs()),
                "f32u4[{i}]: {} vs {}",
                got2[i],
                want[i]
            );
        }
        tensor::set_threads(prev);
    }

    #[test]
    fn u4_kernels_are_bitwise_thread_count_invariant() {
        let _guard = THREAD_TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        let prev = tensor::configured_threads();
        let mut g = Rng::new(0xc0de);
        let (m, k, n) = (300, 70, 41);
        let a: Vec<i8> = (0..m * k).map(|_| (g.below(255) as i32 - 127) as i8).collect();
        let levels = rand_u4_levels(&mut g, k * n, 4);
        let w = U4Weight::from_levels(&levels, n, 1.5e-3).unwrap();
        tensor::set_threads(1);
        let base_raw = matmul_u4(&a, &w, m);
        let mut base_scaled = vec![0.0f32; m * n];
        matmul_i8u4_scaled_into(&mut base_scaled, &a, &w, m, 2e-3, None);
        let af: Vec<f32> = a.iter().map(|&l| l as f32 * 2e-3).collect();
        let mut base_mixed = vec![0.0f32; m * n];
        matmul_f32u4_scaled_into(&mut base_mixed, &af, &w, m, None);
        for threads in [2usize, 3, 4, 8] {
            tensor::set_threads(threads);
            assert_eq!(matmul_u4(&a, &w, m), base_raw, "raw diverged at {threads} threads");
            let mut got = vec![0.0f32; m * n];
            matmul_i8u4_scaled_into(&mut got, &a, &w, m, 2e-3, None);
            assert!(
                got.iter().zip(&base_scaled).all(|(x, y)| x.to_bits() == y.to_bits()),
                "scaled diverged at {threads} threads"
            );
            let mut gotm = vec![0.0f32; m * n];
            matmul_f32u4_scaled_into(&mut gotm, &af, &w, m, None);
            assert!(
                gotm.iter().zip(&base_mixed).all(|(x, y)| x.to_bits() == y.to_bits()),
                "mixed diverged at {threads} threads"
            );
        }
        tensor::set_threads(prev);
    }
}
