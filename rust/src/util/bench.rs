//! Micro-benchmark harness (criterion replacement).
//!
//! Warmup + timed iterations with mean / p50 / p95 / throughput reporting.
//! Used both by `cargo bench` targets (with `harness = false`) and by the
//! `geta bench` CLI subcommand. Results can be appended to a JSON log so
//! the perf pass (EXPERIMENTS.md §Perf) has a machine-readable trail.

use std::time::Duration;

use crate::obs::Stopwatch;
use crate::util::json::Json;

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub p50: Duration,
    pub p95: Duration,
    pub min: Duration,
}

impl BenchResult {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(&self.name)),
            ("iters", Json::Num(self.iters as f64)),
            ("mean_us", Json::Num(self.mean.as_secs_f64() * 1e6)),
            ("p50_us", Json::Num(self.p50.as_secs_f64() * 1e6)),
            ("p95_us", Json::Num(self.p95.as_secs_f64() * 1e6)),
            ("min_us", Json::Num(self.min.as_secs_f64() * 1e6)),
        ])
    }
}

pub struct Bencher {
    pub warmup: usize,
    pub iters: usize,
    pub results: Vec<BenchResult>,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            warmup: 3,
            iters: 20,
            results: Vec::new(),
        }
    }
}

impl Bencher {
    pub fn new(warmup: usize, iters: usize) -> Self {
        Bencher {
            warmup,
            iters,
            results: Vec::new(),
        }
    }

    /// Time `f` and report. The closure's return value is black-boxed to
    /// keep the optimizer from deleting the work.
    pub fn bench<T>(&mut self, name: &str, mut f: impl FnMut() -> T) -> BenchResult {
        for _ in 0..self.warmup {
            black_box(f());
        }
        let mut samples = Vec::with_capacity(self.iters);
        for _ in 0..self.iters {
            let sw = Stopwatch::start();
            black_box(f());
            samples.push(sw.elapsed());
        }
        samples.sort();
        let mean = samples.iter().sum::<Duration>() / samples.len() as u32;
        let res = BenchResult {
            name: name.to_string(),
            iters: self.iters,
            mean,
            p50: samples[samples.len() / 2],
            p95: samples[(samples.len() * 95 / 100).min(samples.len() - 1)],
            min: samples[0],
        };
        println!(
            "{:<44} mean {:>10.1?}  p50 {:>10.1?}  p95 {:>10.1?}  min {:>10.1?}",
            res.name, res.mean, res.p50, res.p95, res.min
        );
        self.results.push(res.clone());
        res
    }

    pub fn write_log(&self, path: &std::path::Path) -> anyhow::Result<()> {
        let arr = Json::Arr(self.results.iter().map(|r| r.to_json()).collect());
        std::fs::write(path, arr.to_string())?;
        Ok(())
    }
}

/// Prevent the optimizer from discarding a value (std::hint::black_box).
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let mut b = Bencher::new(1, 5);
        let r = b.bench("spin", || {
            let mut s = 0u64;
            for i in 0..10_000 {
                s = s.wrapping_add(i);
            }
            s
        });
        assert!(r.mean.as_nanos() > 0);
        assert!(r.min <= r.p50 && r.p50 <= r.p95);
        assert_eq!(b.results.len(), 1);
    }

    #[test]
    fn json_log_shape() {
        let mut b = Bencher::new(0, 2);
        b.bench("x", || 1 + 1);
        let j = b.results[0].to_json();
        assert!(j.get("mean_us").unwrap().as_f64().unwrap() >= 0.0);
    }
}
