//! Tiny CLI argument parser (clap replacement).
//!
//! Grammar: `geta <subcommand> [positional...] [--flag] [--key value]`.
//! Flags may also be written `--key=value`.

use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    pub fn parse(argv: &[String]) -> Args {
        let mut a = Args::default();
        let mut i = 0;
        while i < argv.len() {
            let tok = &argv[i];
            if let Some(stripped) = tok.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    a.options.insert(k.to_string(), v.to_string());
                } else if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    a.options.insert(stripped.to_string(), argv[i + 1].clone());
                    i += 1;
                } else {
                    a.flags.push(stripped.to_string());
                }
            } else if a.subcommand.is_none() {
                a.subcommand = Some(tok.clone());
            } else {
                a.positional.push(tok.clone());
            }
            i += 1;
        }
        a
    }

    pub fn from_env() -> Args {
        let argv: Vec<String> = std::env::args().skip(1).collect();
        Args::parse(&argv)
    }

    pub fn opt(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn opt_or(&self, key: &str, default: &str) -> String {
        self.opt(key).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.opt(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.opt(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_subcommand_and_options() {
        let a = Args::parse(&sv(&["train", "cfgA", "--steps", "100", "--fast", "--lr=0.1"]));
        assert_eq!(a.subcommand.as_deref(), Some("train"));
        assert_eq!(a.positional, vec!["cfgA"]);
        assert_eq!(a.usize_or("steps", 0), 100);
        assert_eq!(a.f64_or("lr", 0.0), 0.1);
        assert!(a.flag("fast"));
        assert!(!a.flag("slow"));
    }

    #[test]
    fn trailing_flag() {
        let a = Args::parse(&sv(&["bench", "--verbose"]));
        assert!(a.flag("verbose"));
        assert!(a.opt("verbose").is_none());
    }

    #[test]
    fn defaults() {
        let a = Args::parse(&sv(&[]));
        assert!(a.subcommand.is_none());
        assert_eq!(a.opt_or("x", "d"), "d");
    }
}
