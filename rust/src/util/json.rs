//! Minimal JSON parser + writer (serde_json replacement).
//!
//! Supports the full JSON grammar (objects, arrays, strings with escapes,
//! numbers, bools, null). Used for AOT manifests, model configs,
//! experiment configs, golden test vectors and report emission.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // ----------------------------------------------------------- accessors
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Like `get` but returns an error naming the missing key — manifests
    /// are trusted-but-verified inputs, so failures must be diagnosable.
    pub fn req(&self, key: &str) -> anyhow::Result<&Json> {
        self.get(key)
            .ok_or_else(|| anyhow::anyhow!("missing json key `{key}`"))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|f| f as i64)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key)
            .and_then(|v| v.as_str())
            .unwrap_or(default)
            .to_string()
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.as_f64()).unwrap_or(default)
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.as_usize()).unwrap_or(default)
    }

    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        self.get(key).and_then(|v| v.as_bool()).unwrap_or(default)
    }

    pub fn usize_arr(&self, key: &str) -> Vec<usize> {
        self.get(key)
            .and_then(|v| v.as_arr())
            .map(|a| a.iter().filter_map(|x| x.as_usize()).collect())
            .unwrap_or_default()
    }

    pub fn f32_arr(&self) -> Vec<f32> {
        self.as_arr()
            .map(|a| a.iter().filter_map(|x| x.as_f64()).map(|f| f as f32).collect())
            .unwrap_or_default()
    }

    // --------------------------------------------------------- constructors
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_f64(vals: &[f64]) -> Json {
        Json::Arr(vals.iter().map(|v| Json::Num(*v)).collect())
    }

    pub fn str(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}

// ------------------------------------------------------------------ parsing
pub fn parse(text: &str) -> Result<Json, JsonError> {
    let mut p = Parser {
        b: text.as_bytes(),
        pos: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.b.len() {
        return Err(p.err("trailing content"));
    }
    Ok(v)
}

pub fn parse_file(path: &std::path::Path) -> anyhow::Result<Json> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("read {}: {e}", path.display()))?;
    parse(&text).map_err(|e| anyhow::anyhow!("parse {}: {e}", path.display()))
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected `{}`", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected `{word}`")))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected `,` or `}`")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.skip_ws();
            a.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(a));
                }
                _ => return Err(self.err("expected `,` or `]`")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.pos + 1..self.pos + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 code point
                    let start = self.pos;
                    self.pos += 1;
                    while self.pos < self.b.len() && (self.b[self.pos] & 0xC0) == 0x80 {
                        self.pos += 1;
                    }
                    s.push_str(std::str::from_utf8(&self.b[start..self.pos]).map_err(|_| {
                        self.err("invalid utf-8")
                    })?);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

// ------------------------------------------------------------------ writing
impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => {
                write!(f, "\"")?;
                for c in s.chars() {
                    match c {
                        '"' => write!(f, "\\\"")?,
                        '\\' => write!(f, "\\\\")?,
                        '\n' => write!(f, "\\n")?,
                        '\t' => write!(f, "\\t")?,
                        '\r' => write!(f, "\\r")?,
                        c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
                        c => write!(f, "{c}")?,
                    }
                }
                write!(f, "\"")
            }
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}:{}", Json::Str(k.clone()), v)?;
                }
                write!(f, "}}")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("\"hi\\n\"").unwrap(), Json::Str("hi\n".into()));
    }

    #[test]
    fn parses_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": "c"}], "d": {"e": false}}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("d").unwrap().get("e").unwrap().as_bool(),
            Some(false)
        );
    }

    #[test]
    fn parses_unicode_escape() {
        assert_eq!(parse("\"\\u0041\"").unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("12 34").is_err());
        assert!(parse("{\"a\":}").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"name":"x","arr":[1,2.5,null,true],"nested":{"k":"v \"q\""}}"#;
        let v = parse(src).unwrap();
        let out = v.to_string();
        assert_eq!(parse(&out).unwrap(), v);
    }

    #[test]
    fn accessors() {
        let v = parse(r#"{"s": "x", "n": 3, "b": true, "a": [1,2]}"#).unwrap();
        assert_eq!(v.str_or("s", "d"), "x");
        assert_eq!(v.str_or("missing", "d"), "d");
        assert_eq!(v.usize_or("n", 0), 3);
        assert_eq!(v.bool_or("b", false), true);
        assert_eq!(v.usize_arr("a"), vec![1, 2]);
        assert!(v.req("missing").is_err());
    }
}
