//! In-repo infrastructure substrates.
//!
//! The build environment is fully offline: every dependency is a vendored
//! path crate (rust/vendor/), so the usual ecosystem crates
//! (serde/serde_json, clap, rand, criterion, proptest, tokio) are
//! unavailable. Each submodule here is a small, well-tested replacement
//! for the slice of functionality this project needs.

pub mod json;
pub mod rng;
pub mod cli;
pub mod bench;
pub mod prop;
pub mod table;
