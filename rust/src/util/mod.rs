//! In-repo infrastructure substrates.
//!
//! The build environment is fully offline: only the `xla` crate's
//! dependency closure exists in the cargo cache, so the usual ecosystem
//! crates (serde/serde_json, clap, rand, criterion, proptest, tokio) are
//! unavailable. Each submodule here is a small, well-tested replacement
//! for the slice of functionality this project needs.

pub mod json;
pub mod rng;
pub mod cli;
pub mod bench;
pub mod prop;
pub mod table;
