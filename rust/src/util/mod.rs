//! In-repo infrastructure substrates.
//!
//! The build environment is fully offline: every dependency is a vendored
//! path crate (rust/vendor/), so the usual ecosystem crates
//! (serde/serde_json, clap, rand, criterion, proptest, tokio) are
//! unavailable. Each submodule here is a small, well-tested replacement
//! for the slice of functionality this project needs.

pub mod json;
pub mod rng;
pub mod cli;
pub mod bench;
pub mod prop;
pub mod table;

/// Crash-safe file replacement: write `bytes` to a temp file in the
/// target's directory, fsync it, then atomically rename over `path`
/// (same-filesystem rename is atomic on every platform we build for).
///
/// The invariant callers get: **at every instant, `path` either holds
/// its previous complete contents or the new complete contents** — a
/// crash, kill, or full disk mid-write leaves the previous artifact
/// intact and readable. `.geta` containers and `.getackpt` training
/// checkpoints (what `--resume` replays from) both write through here;
/// `test_deploy.rs` / `test_shrink.rs` pin the mid-write-crash story.
///
/// The temp name embeds the pid so two processes exporting side by side
/// cannot collide on the scratch file; last rename wins the target,
/// which is the same guarantee plain `fs::write` had. On any error the
/// scratch file is cleaned up.
pub fn atomic_write(path: &std::path::Path, bytes: &[u8]) -> std::io::Result<()> {
    use std::io::Write as _;
    let name = path
        .file_name()
        .map(|n| n.to_string_lossy().into_owned())
        .unwrap_or_else(|| "artifact".to_string());
    let tmp = path.with_file_name(format!(".{name}.{}.tmp", std::process::id()));
    let res = (|| {
        let mut f = std::fs::File::create(&tmp)?;
        f.write_all(bytes)?;
        // data must be durable *before* the rename publishes it, or a
        // power cut could publish a hole
        f.sync_all()?;
        drop(f);
        std::fs::rename(&tmp, path)?;
        // best effort: make the rename itself durable (directory entry)
        if let Some(dir) = path.parent().filter(|d| !d.as_os_str().is_empty()) {
            if let Ok(d) = std::fs::File::open(dir) {
                let _ = d.sync_all();
            }
        }
        Ok(())
    })();
    if res.is_err() {
        let _ = std::fs::remove_file(&tmp);
    }
    res
}

#[cfg(test)]
mod tests {
    #[test]
    fn atomic_write_replaces_and_survives_a_simulated_crash() {
        let dir = std::env::temp_dir().join(format!("geta_atomic_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let target = dir.join("artifact.bin");

        super::atomic_write(&target, b"generation-1").unwrap();
        assert_eq!(std::fs::read(&target).unwrap(), b"generation-1");

        // A crash mid-write is a stray truncated temp file; the published
        // artifact must be untouched by its existence.
        let stray = dir.join(format!(".artifact.bin.{}.tmp", std::process::id()));
        std::fs::write(&stray, b"gen").unwrap();
        assert_eq!(std::fs::read(&target).unwrap(), b"generation-1");

        // The next successful write claims the scratch name and replaces
        // the artifact whole.
        super::atomic_write(&target, b"generation-2-longer").unwrap();
        assert_eq!(std::fs::read(&target).unwrap(), b"generation-2-longer");
        assert!(!stray.exists(), "scratch file is consumed by the rename");

        let _ = std::fs::remove_dir_all(&dir);
    }
}
