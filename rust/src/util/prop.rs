//! Property-testing helper (proptest replacement).
//!
//! `check(cases, gen, prop)` runs `prop` against `cases` generated inputs
//! and, on failure, retries with progressively "smaller" regenerated
//! inputs (shrink-by-regeneration: the generator receives a shrink factor
//! in (0, 1] it can use to scale sizes/magnitudes). Failures report the
//! seed so the exact case replays.

use crate::util::rng::Rng;

pub struct Gen<'a> {
    pub rng: &'a mut Rng,
    /// 1.0 for the initial attempt; reduced toward 0 while shrinking.
    pub scale: f64,
}

impl<'a> Gen<'a> {
    pub fn size(&mut self, max: usize) -> usize {
        let m = ((max as f64 * self.scale).ceil() as usize).max(1);
        1 + self.rng.below(m)
    }

    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        self.rng.range(lo as f64, hi as f64) as f32
    }

    pub fn vec_normal(&mut self, n: usize, scale: f32) -> Vec<f32> {
        let mut v = vec![0.0; n];
        self.rng.fill_normal(&mut v, scale * self.scale as f32);
        v
    }
}

/// Run a property with shrinking. Panics with the failing seed on failure.
pub fn check<I: std::fmt::Debug>(
    cases: usize,
    mut generate: impl FnMut(&mut Gen) -> I,
    mut prop: impl FnMut(&I) -> Result<(), String>,
) {
    let base_seed = 0xC0FFEE_u64;
    for case in 0..cases {
        let seed = base_seed.wrapping_add(case as u64 * 0x9E37);
        let mut rng = Rng::new(seed);
        let input = generate(&mut Gen {
            rng: &mut rng,
            scale: 1.0,
        });
        if let Err(msg) = prop(&input) {
            // shrink: regenerate same-seed inputs at smaller scales and
            // report the smallest still-failing one.
            let mut best: (f64, String, String) = (1.0, msg, format!("{input:?}"));
            for k in 1..=6 {
                let scale = 1.0 / (1 << k) as f64;
                let mut rng = Rng::new(seed);
                let small = generate(&mut Gen {
                    rng: &mut rng,
                    scale,
                });
                if let Err(m) = prop(&small) {
                    best = (scale, m, format!("{small:?}"));
                }
            }
            panic!(
                "property failed (seed={seed:#x}, case {case}, shrink scale {}):\n  {}\n  input: {}",
                best.0, best.1, best.2
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        check(
            25,
            |g| {
                let n = g.size(16);
                g.vec_normal(n, 1.0)
            },
            |v: &Vec<f32>| {
                count += 1;
                if v.iter().all(|x| x.is_finite()) {
                    Ok(())
                } else {
                    Err("non-finite".into())
                }
            },
        );
        assert!(count >= 25);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_seed() {
        check(
            10,
            |g| g.size(100),
            |n: &usize| if *n < 1 { Ok(()) } else { Err(format!("n={n}")) },
        );
    }
}
