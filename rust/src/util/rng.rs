//! Deterministic PRNG (SplitMix64 core) — `rand` crate replacement.
//!
//! Every stochastic component in the system (data generation, batch
//! shuffling, weight init for Rust-side reference nets, property tests)
//! draws from this so runs are reproducible from a single seed.

#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
    /// cached second normal from the Box-Muller pair
    spare: Option<f64>,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng {
            state: seed.wrapping_add(0x9E3779B97F4A7C15),
            spare: None,
        }
    }

    /// Derive an independent stream (for per-worker / per-dataset rngs).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0xBF58476D1CE4E5B9))
    }

    /// Raw generator state (SplitMix64 counter + cached Box-Muller spare)
    /// for checkpointing; [`Rng::from_state`] rebuilds an identical stream.
    pub fn state(&self) -> (u64, Option<f64>) {
        (self.state, self.spare)
    }

    /// Rebuild a generator from [`Rng::state`] output — the continuation
    /// produces exactly the sequence the saved generator would have.
    pub fn from_state(state: u64, spare: Option<f64>) -> Rng {
        Rng { state, spare }
    }

    pub fn next_u64(&mut self) -> u64 {
        // SplitMix64 (Steele et al.) — passes BigCrush, 1 mul-xor chain.
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.uniform() * (hi - lo)
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // multiply-shift; bias is negligible for n << 2^64
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Standard normal (Box-Muller with spare caching).
    pub fn normal(&mut self) -> f64 {
        if let Some(s) = self.spare.take() {
            return s;
        }
        loop {
            let u = self.uniform();
            if u <= f64::MIN_POSITIVE {
                continue;
            }
            let v = self.uniform();
            let r = (-2.0 * u.ln()).sqrt();
            let th = 2.0 * std::f64::consts::PI * v;
            self.spare = Some(r * th.sin());
            return r * th.cos();
        }
    }

    pub fn normal_f32(&mut self, scale: f32) -> f32 {
        (self.normal() as f32) * scale
    }

    /// Fill a slice with N(0, scale^2) samples.
    pub fn fill_normal(&mut self, buf: &mut [f32], scale: f32) {
        for v in buf.iter_mut() {
            *v = self.normal_f32(scale);
        }
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Random permutation of 0..n.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut p: Vec<usize> = (0..n).collect();
        self.shuffle(&mut p);
        p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(8);
        assert_ne!(Rng::new(7).next_u64(), c.next_u64());
    }

    #[test]
    fn uniform_in_range_and_roughly_uniform() {
        let mut r = Rng::new(1);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(2);
        let n = 50_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            s1 += x;
            s2 += x * x;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn below_covers_all_buckets() {
        let mut r = Rng::new(3);
        let mut hits = [0usize; 7];
        for _ in 0..7000 {
            hits[r.below(7)] += 1;
        }
        for (i, h) in hits.iter().enumerate() {
            assert!(*h > 700, "bucket {i}: {h}");
        }
    }

    #[test]
    fn permutation_is_valid() {
        let mut r = Rng::new(4);
        let p = r.permutation(50);
        let mut seen = vec![false; 50];
        for i in p {
            assert!(!seen[i]);
            seen[i] = true;
        }
        assert!(seen.iter().all(|&b| b));
    }

    #[test]
    fn state_round_trip_continues_identically() {
        let mut a = Rng::new(11);
        // consume an odd number of normals so a Box-Muller spare is cached
        for _ in 0..7 {
            a.normal();
        }
        let (st, sp) = a.state();
        assert!(sp.is_some());
        let mut b = Rng::from_state(st, sp);
        for _ in 0..32 {
            assert_eq!(a.normal().to_bits(), b.normal().to_bits());
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn fork_streams_diverge() {
        let mut root = Rng::new(5);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let va: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        assert_ne!(va, vb);
    }
}
