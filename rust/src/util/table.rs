//! Plain-text table renderer for the paper-table repro harnesses.

pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("\n== {} ==\n", self.title));
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::from("| ");
            for (c, w) in cells.iter().zip(widths) {
                line.push_str(&format!("{c:<w$} | ", w = w));
            }
            line.trim_end().to_string()
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        out.push('\n');
        out.push_str(&format!(
            "|{}|\n",
            widths
                .iter()
                .map(|w| "-".repeat(w + 2))
                .collect::<Vec<_>>()
                .join("|")
        ));
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }

    /// Markdown form for EXPERIMENTS.md.
    pub fn markdown(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!("| {} |\n", self.headers.join(" | ")));
        out.push_str(&format!(
            "|{}|\n",
            self.headers.iter().map(|_| "---").collect::<Vec<_>>().join("|")
        ));
        for row in &self.rows {
            out.push_str(&format!("| {} |\n", row.join(" | ")));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("T", &["method", "acc"]);
        t.row(vec!["GETA".into(), "91.42".into()]);
        t.row(vec!["baseline-long-name".into(), "91.70".into()]);
        let s = t.render();
        assert!(s.contains("== T =="));
        assert!(s.contains("GETA"));
        let md = t.markdown();
        assert!(md.starts_with("| method | acc |"));
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn arity_checked() {
        let mut t = Table::new("T", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }
}
