//! Shared helpers for the integration suites.

use std::path::PathBuf;

pub fn art_dir() -> PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// The backend-or-skip policy, held in one place: skipping a model is
/// legitimate only when no usable backend exists for it — the native
/// engine does not implement the family and either artifacts/`pjrt` are
/// absent or the vendored xla stub is what is linked. A `pjrt` build with
/// real bindings and artifacts failing is a regression and panics instead
/// of silently skipping.
#[allow(dead_code)]
pub fn skip_or_panic(model: &str, err: &anyhow::Error) {
    let stub_linked = err.to_string().contains("xla stub");
    let pjrt_ready = cfg!(feature = "pjrt")
        && geta::runtime::has_artifact(&art_dir(), model)
        && !stub_linked;
    assert!(!pjrt_ready, "{model} backend should be available but failed: {err}");
    eprintln!("skipping {model}: {err}");
}
