//! Shared helpers for the integration suites.

use std::path::PathBuf;

pub fn art_dir() -> PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// The backend-or-skip policy, held in one place.
///
/// A model whose family has a native lowering may **never** skip: the
/// interpreter serves it on every machine, so a backend failure there is a
/// regression and panics. A model whose family is *not* lowered must fail
/// with an error naming the family (strict-fail, still no silent skip);
/// skipping is then legitimate only because no backend exists for it —
/// unless a `pjrt` build with real bindings and artifacts should have
/// served it, which also panics.
#[allow(dead_code)]
pub fn skip_or_panic(model: &str, err: &anyhow::Error) {
    if let Some(cfg) = geta::runtime::native::embedded_config(model) {
        let fam = cfg.str_or("family", "");
        assert!(
            !geta::runtime::native::lowered_families().contains(&fam.as_str()),
            "{model} (family `{fam}`) has a native lowering and may never skip: {err}"
        );
        assert!(
            err.to_string().contains(&fam),
            "{model}: unlowered-family error must name the family `{fam}`: {err}"
        );
    }
    let stub_linked = err.to_string().contains("xla stub");
    let pjrt_ready = cfg!(feature = "pjrt")
        && geta::runtime::has_artifact(&art_dir(), model)
        && !stub_linked;
    assert!(!pjrt_ready, "{model} backend should be available but failed: {err}");
    eprintln!("skipping {model}: {err}");
}

/// Corruption sweep for a strict binary reader (`parses` returns whether
/// the bytes parsed): at every 64-byte window boundary, (a) the prefix
/// truncated there must *fail* — typed, never a panic — and (b) flipping
/// one bit there must never panic the reader (a typed error or a benign
/// payload change are both acceptable; silent acceptance of a truncation
/// is not). Pins the crash-safety half of the serving story: a torn or
/// damaged artifact must be rejected, not served.
#[allow(dead_code)]
pub fn assert_corruption_safe(label: &str, bytes: &[u8], parses: &dyn Fn(&[u8]) -> bool) {
    use std::panic::{catch_unwind, AssertUnwindSafe};
    assert!(parses(bytes), "{label}: pristine bytes must parse");
    let mut off = 0;
    while off < bytes.len() {
        match catch_unwind(AssertUnwindSafe(|| parses(&bytes[..off]))) {
            Ok(ok) => assert!(
                !ok,
                "{label}: truncation to {off}/{} bytes parsed as valid",
                bytes.len()
            ),
            Err(_) => panic!("{label}: truncation to {off} bytes panicked the reader"),
        }
        let mut flipped = bytes.to_vec();
        flipped[off] ^= 0x80;
        assert!(
            catch_unwind(AssertUnwindSafe(|| {
                parses(&flipped);
            }))
            .is_ok(),
            "{label}: flipping bit 7 of byte {off} panicked the reader"
        );
        off += 64;
    }
}
