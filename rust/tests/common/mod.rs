//! Shared helpers for the integration suites.

use std::path::PathBuf;

pub fn art_dir() -> PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// The backend-or-skip policy, held in one place.
///
/// A model whose family has a native lowering may **never** skip: the
/// interpreter serves it on every machine, so a backend failure there is a
/// regression and panics. A model whose family is *not* lowered must fail
/// with an error naming the family (strict-fail, still no silent skip);
/// skipping is then legitimate only because no backend exists for it —
/// unless a `pjrt` build with real bindings and artifacts should have
/// served it, which also panics.
#[allow(dead_code)]
pub fn skip_or_panic(model: &str, err: &anyhow::Error) {
    if let Some(cfg) = geta::runtime::native::embedded_config(model) {
        let fam = cfg.str_or("family", "");
        assert!(
            !geta::runtime::native::lowered_families().contains(&fam.as_str()),
            "{model} (family `{fam}`) has a native lowering and may never skip: {err}"
        );
        assert!(
            err.to_string().contains(&fam),
            "{model}: unlowered-family error must name the family `{fam}`: {err}"
        );
    }
    let stub_linked = err.to_string().contains("xla stub");
    let pjrt_ready = cfg!(feature = "pjrt")
        && geta::runtime::has_artifact(&art_dir(), model)
        && !stub_linked;
    assert!(!pjrt_ready, "{model} backend should be available but failed: {err}");
    eprintln!("skipping {model}: {err}");
}
