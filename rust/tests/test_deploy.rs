//! Deployment round-trips: train briefly -> `export` -> re-read the
//! `.geta` file -> `infer`, per exportable family.
//!
//! Four obligations per family:
//!   1. **Parity** — the packed-integer engine's logits match the native
//!      interpreter's masked-model eval within 1e-4 (packed levels
//!      dequantize to exactly the fake-quantized weights; slicing removes
//!      only channels whose masked contribution is exactly zero).
//!   2. **Int8 parity** — the integer compute path (`--int8`: resident i8
//!      levels, i32-accumulated GEMMs, scale epilogue) holds the same
//!      1e-4 bar against the same masked eval, and its results are
//!      bitwise identical across worker-thread counts.
//!   3. **Size** — the artifact on disk is strictly smaller than the dense
//!      f32 parameter bytes of the original architecture.
//!   4. **Speed** (mlp + resnet) — compressed inference throughput (both
//!      kernels) is at least the dense-f32 throughput through the same
//!      executor.
//!
//! Bits are capped at 8 here (`b_u = 8`): that is the regime the integer
//! path serves — a site trained past 8 bits falls back to f32 per tensor
//! and the int8 assertions would silently test nothing.

mod common;

use common::art_dir;
use geta::config::ExperimentConfig;
use geta::coordinator::{Compressor as _, GetaCompressor, Trainer};
use geta::deploy::{self, GetaEngine, KernelKind};
use geta::graph;
use geta::optim::qasso::StageMask;
use geta::runtime::Backend as _;

fn trainer(exp: ExperimentConfig) -> Trainer {
    let model = exp.model.clone();
    match Trainer::new(&art_dir(), exp) {
        Ok(t) => t,
        Err(e) => {
            common::skip_or_panic(&model, &e);
            panic!("{model} has a native lowering; skip_or_panic must not return");
        }
    }
}

fn deploy_exp(model: &str, sparsity: f64) -> ExperimentConfig {
    let mut e = ExperimentConfig::defaults_for(model);
    e.scale_steps(0.1);
    e.n_train = 192;
    e.n_eval = 96;
    e.qasso.target_group_sparsity = sparsity;
    // serve-ready bit range: every weight site stays i8-eligible (see the
    // module docs)
    e.qasso.b_u = e.qasso.b_u.min(8.0);
    e.qasso.b_l = e.qasso.b_l.min(e.qasso.b_u);
    e.qasso.init_bits = e.qasso.init_bits.min(8.0);
    e
}

/// Best-of-n wall clock of one `infer` call, in seconds.
fn time_infer(engine: &GetaEngine, x: &geta::runtime::HostArray, n: usize) -> f64 {
    engine.infer(x).unwrap(); // warm
    let mut best = f64::INFINITY;
    for _ in 0..n {
        let t0 = std::time::Instant::now();
        std::hint::black_box(engine.infer(x).unwrap());
        best = best.min(t0.elapsed().as_secs_f64());
    }
    best
}

fn roundtrip(model: &str, sparsity: f64, check_speed: bool) {
    let t = trainer(deploy_exp(model, sparsity));
    let mut g = GetaCompressor::new(&*t.engine, &t.exp, StageMask::default()).unwrap();
    let mut trained = t.run_trained(&mut g).unwrap();
    let dense_params = trained.params.clone();
    let cfg = t.engine.manifest().config.clone();
    let space = graph::search_space_for(&cfg).unwrap();
    let pruned: Vec<bool> = g.pruned_mask().unwrap().to_vec();
    assert!(
        pruned.iter().any(|&p| p),
        "{model}: nothing pruned at target sparsity {sparsity}; roundtrip would be trivial"
    );

    // export -> bytes on disk
    let path = std::env::temp_dir().join(format!("geta_roundtrip_{model}.geta"));
    let (container, cm) = deploy::export_to_file(
        &cfg,
        &t.engine.site_specs(),
        &space.groups,
        &pruned,
        &t.costs,
        &mut trained.params,
        &trained.q,
        &path,
    )
    .unwrap();
    let disk = std::fs::metadata(&path).unwrap().len() as usize;
    assert!(
        disk < cm.size_fp32_before,
        "{model}: {disk} bytes on disk not smaller than dense f32 {} bytes",
        cm.size_fp32_before
    );

    // strict re-read -> engine
    let engine = GetaEngine::load(&path).unwrap();
    std::fs::remove_file(&path).ok();
    assert_eq!(engine.model, model);

    // the integer compute path over the same container; with b_u capped
    // at 8, every packed weight site must become i8-resident
    let int_engine = GetaEngine::from_container_kernel(&container, KernelKind::Int8).unwrap();
    assert!(
        int_engine.int_sites() > 0,
        "{model}: no weight site became i8-resident at b_u = 8"
    );

    // parity vs masked interpreter eval, two eval batches, both kernels
    let bs = t.batch_size();
    for b in 0..2usize {
        let idxs: Vec<usize> = (b * bs..(b + 1) * bs).collect();
        if *idxs.last().unwrap() >= t.eval_data.len() {
            break;
        }
        let (x, y) = t.eval_data.batch(&idxs);
        let masked = t
            .engine
            .eval_logits(&trained.params, &trained.q, &x, &y)
            .unwrap();
        for (label, e) in [("f32", &engine), ("int8", &int_engine)] {
            let got = e.infer(&x).unwrap();
            assert_eq!(got.len(), masked.len(), "{model}/{label}: logit count");
            for i in 0..got.len() {
                assert!(
                    (got[i] - masked[i]).abs() <= 1e-4 * (1.0 + masked[i].abs()),
                    "{model}/{label}: logit[{i}] = {} vs masked {} (batch {b})",
                    got[i],
                    masked[i]
                );
            }
        }
    }

    // int8 results are bitwise identical at 1 and 4 worker threads (i32
    // accumulation is associative; sharding happens at micro-batch bounds)
    {
        let n = (2 * bs).min(t.eval_data.len());
        let idxs: Vec<usize> = (0..n).collect();
        let (x, _y) = t.eval_data.batch(&idxs);
        let one = {
            let mut e = GetaEngine::from_container_kernel(&container, KernelKind::Int8).unwrap();
            e.threads = 1;
            e.infer(&x).unwrap()
        };
        let four = {
            let mut e = GetaEngine::from_container_kernel(&container, KernelKind::Int8).unwrap();
            e.threads = 4;
            e.infer(&x).unwrap()
        };
        assert_eq!(one, four, "{model}: int8 logits differ across thread counts");
    }

    // throughput: neither compressed kernel may be slower than the
    // dense-f32 model through the identical executor (the int8-vs-f32
    // comparison itself is tracked by the bench-artifact CI job over
    // best-of timings, not asserted under test parallelism)
    if check_speed {
        let mut dense = GetaEngine::dense(&cfg, dense_params).unwrap();
        dense.threads = 1;
        let mut comp = GetaEngine::from_container(&container).unwrap();
        comp.threads = 1;
        let mut int_comp = GetaEngine::from_container_kernel(&container, KernelKind::Int8).unwrap();
        int_comp.threads = 1;
        let idxs: Vec<usize> = (0..bs).collect();
        let (x, _y) = t.eval_data.batch(&idxs);
        let dense_s = time_infer(&dense, &x, 5);
        for (label, e) in [("f32", &comp), ("int8", &int_comp)] {
            let comp_s = time_infer(e, &x, 5);
            assert!(
                comp_s <= dense_s,
                "{model}/{label}: compressed {comp_s:.6}s/batch slower than dense \
                 {dense_s:.6}s/batch (group sparsity {:.2})",
                trained.result.group_sparsity
            );
        }
    }
}

/// A `.geta` container damaged on disk — truncated or bit-flipped at any
/// 64-byte window — must be rejected with a typed error (truncations) and
/// must never panic or over-allocate the strict reader (bit flips may at
/// worst land in payload bits and parse benignly). This is the artifact a
/// server loads at request time; damage has to fail the load, not the
/// process (`ModelCache` then retries once a valid artifact lands).
#[test]
fn corrupt_geta_containers_fail_typed_never_panic() {
    let art = geta::report::train_export(&art_dir(), "mlp_tiny", 0.1, 0.5, 8.0).unwrap();
    let bytes = art.container.to_bytes();
    common::assert_corruption_safe(".geta", &bytes, &|b| {
        deploy::GetaContainer::from_bytes(b).is_ok()
    });
}

#[test]
fn roundtrip_mlp() {
    roundtrip("mlp_tiny", 0.5, true);
}

#[test]
fn roundtrip_resnet() {
    roundtrip("resnet_mini", 0.5, true);
}

#[test]
fn roundtrip_vgg() {
    roundtrip("vgg7_mini", 0.35, false);
}

#[test]
fn roundtrip_vit() {
    roundtrip("vit_mini", 0.3, false);
}

#[test]
fn roundtrip_bert() {
    roundtrip("bert_mini", 0.3, false);
}

#[test]
fn roundtrip_gpt() {
    roundtrip("gpt_mini", 0.3, false);
}

#[test]
fn roundtrip_swin() {
    roundtrip("swin_mini", 0.3, false);
}
