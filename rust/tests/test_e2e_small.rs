//! End-to-end integration: full GETA runs (heavily step-scaled) plus the
//! sequential baseline. These are the contract tests for "all layers
//! compose".
//!
//! Backend selection is automatic: with AOT artifacts (and the `pjrt`
//! feature) the compiled-HLO engine runs; without them the mlp workloads
//! run on the native reference backend, so `cargo test` exercises the
//! warm-up → projection → joint → cool-down pipeline on every machine.
//! Model families the native backend does not implement (bert here) skip
//! only when no backend can serve them.

mod common;

use common::art_dir;
use geta::runtime::Backend as _;
use geta::baselines;
use geta::config::ExperimentConfig;
use geta::coordinator::{GetaCompressor, Trainer};
use geta::graph;
use geta::optim::qasso::StageMask;

/// Build a trainer with whatever backend is available; `None` (with a
/// skip note) only when no backend can serve the model — see
/// `common::skip_or_panic` for the policy.
fn trainer(exp: ExperimentConfig) -> Option<Trainer> {
    let model = exp.model.clone();
    match Trainer::new(&art_dir(), exp) {
        Ok(t) => Some(t),
        Err(e) => {
            common::skip_or_panic(&model, &e);
            None
        }
    }
}

fn small_exp(model: &str, sparsity: f64) -> ExperimentConfig {
    let mut e = ExperimentConfig::defaults_for(model);
    e.scale_steps(0.12);
    e.n_train = 256;
    e.n_eval = 128;
    e.qasso.target_group_sparsity = sparsity;
    e
}

#[test]
fn geta_mlp_learns_and_compresses() {
    // never skipped: mlp_tiny always has the native backend
    let t = trainer(small_exp("mlp_tiny", 0.4)).expect("mlp backend is always available");
    let mut g = GetaCompressor::new(&*t.engine, &t.exp, StageMask::default()).unwrap();
    let r = t.run(&mut g).unwrap();
    assert!(r.accuracy > 60.0, "acc {}", r.accuracy);
    assert!((r.group_sparsity - 0.4).abs() < 0.02, "sparsity {}", r.group_sparsity);
    assert!(r.rel_bops < 60.0, "rel bops {}", r.rel_bops);
    assert!(
        r.avg_bits >= t.exp.qasso.b_l as f64 - 0.1 && r.avg_bits <= t.exp.qasso.b_u as f64 + 0.1,
        "bits {}",
        r.avg_bits
    );
    // loss decreased over training
    assert!(r.final_loss < r.trace.losses[0] as f64, "no learning");
}

#[test]
fn geta_bert_span_task() {
    let Some(t) = trainer(small_exp("bert_mini", 0.3)) else { return };
    let mut g = GetaCompressor::new(&*t.engine, &t.exp, StageMask::default()).unwrap();
    let r = t.run(&mut g).unwrap();
    assert!(r.em.is_some() && r.f1.is_some());
    assert!(r.f1.unwrap() >= r.em.unwrap() - 1e-9); // F1 dominates EM
    assert!((r.group_sparsity - 0.3).abs() < 0.05);
}

#[test]
fn prune_then_ptq_baseline_runs() {
    let Some(t) = trainer(small_exp("mlp_tiny", 0.4)) else { return };
    let space = graph::search_space_for(&t.engine.manifest().config).unwrap();
    let params = t.engine.init_params(0);
    let mut m = baselines::PruneThenPtq::new(
        t.exp.qasso.clone(),
        space.groups,
        t.engine.site_specs(),
        baselines::base_opt(&t.exp),
        &params,
        8.0,
        "HESSO+PTQ",
    );
    let r = t.run(&mut m).unwrap();
    // PTQ pins every site to 8 bits
    assert!((r.avg_bits - 8.0).abs() < 0.2, "bits {}", r.avg_bits);
    assert!(r.group_sparsity > 0.3);
}

#[test]
fn unstructured_baseline_density_accounting() {
    let Some(t) = trainer(small_exp("mlp_tiny", 0.0)) else { return };
    let steps = t.exp.total_steps();
    let mut m = baselines::UnstructuredJoint::new(
        0.5, 4.0, 16.0, baselines::base_opt(&t.exp), steps, "unstructured",
    );
    let r = t.run(&mut m).unwrap();
    // BOPs must reflect the 0.5 density even though no groups are pruned
    assert_eq!(r.group_sparsity, 0.0);
    assert!(r.rel_bops < 60.0, "rel bops {}", r.rel_bops);
}

#[test]
fn stage_ablation_variants_run() {
    let Some(t) = trainer(small_exp("mlp_tiny", 0.4)) else { return };
    for mask in [
        StageMask { warmup: false, ..Default::default() },
        StageMask { projection: false, ..Default::default() },
        StageMask { joint: false, ..Default::default() },
        StageMask { cooldown: false, ..Default::default() },
    ] {
        let mut g = GetaCompressor::new(&*t.engine, &t.exp, mask).unwrap();
        let r = t.run(&mut g).unwrap();
        // sparsity target must hold even without the joint stage (one-shot
        // fallback) — the whole point of white-box control
        assert!(
            (r.group_sparsity - 0.4).abs() < 0.05,
            "mask {mask:?}: sparsity {}",
            r.group_sparsity
        );
    }
}

#[test]
fn seeds_change_data_but_not_contract() {
    let mut e1 = small_exp("mlp_tiny", 0.4);
    e1.seed = 11;
    let t = trainer(e1).expect("mlp backend is always available");
    let mut g = GetaCompressor::new(&*t.engine, &t.exp, StageMask::default()).unwrap();
    let r = t.run(&mut g).unwrap();
    assert!((r.group_sparsity - 0.4).abs() < 0.02);
    assert!(r.accuracy > 50.0);
}
