//! End-to-end integration: full GETA runs (heavily step-scaled) plus the
//! sequential baseline, over the real artifacts. These are the contract
//! tests for "all layers compose".

use geta::baselines;
use geta::config::ExperimentConfig;
use geta::coordinator::{GetaCompressor, Trainer};
use geta::graph;
use geta::optim::qasso::StageMask;

fn art() -> Option<std::path::PathBuf> {
    let p = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if p.join("index.json").exists() {
        Some(p)
    } else {
        eprintln!("skipping: run `make artifacts`");
        None
    }
}

fn small_exp(model: &str, sparsity: f64) -> ExperimentConfig {
    let mut e = ExperimentConfig::defaults_for(model);
    e.scale_steps(0.12);
    e.n_train = 256;
    e.n_eval = 128;
    e.qasso.target_group_sparsity = sparsity;
    e
}

#[test]
fn geta_mlp_learns_and_compresses() {
    let Some(dir) = art() else { return };
    let t = Trainer::new(&dir, small_exp("mlp_tiny", 0.4)).unwrap();
    let mut g = GetaCompressor::new(&t.engine, &t.exp, StageMask::default()).unwrap();
    let r = t.run(&mut g).unwrap();
    assert!(r.accuracy > 60.0, "acc {}", r.accuracy);
    assert!((r.group_sparsity - 0.4).abs() < 0.02, "sparsity {}", r.group_sparsity);
    assert!(r.rel_bops < 60.0, "rel bops {}", r.rel_bops);
    assert!(
        r.avg_bits >= t.exp.qasso.b_l as f64 - 0.1 && r.avg_bits <= t.exp.qasso.b_u as f64 + 0.1,
        "bits {}",
        r.avg_bits
    );
    // loss decreased over training
    assert!(r.final_loss < r.trace.losses[0] as f64, "no learning");
}

#[test]
fn geta_bert_span_task() {
    let Some(dir) = art() else { return };
    let t = Trainer::new(&dir, small_exp("bert_mini", 0.3)).unwrap();
    let mut g = GetaCompressor::new(&t.engine, &t.exp, StageMask::default()).unwrap();
    let r = t.run(&mut g).unwrap();
    assert!(r.em.is_some() && r.f1.is_some());
    assert!(r.f1.unwrap() >= r.em.unwrap() - 1e-9); // F1 dominates EM
    assert!((r.group_sparsity - 0.3).abs() < 0.05);
}

#[test]
fn prune_then_ptq_baseline_runs() {
    let Some(dir) = art() else { return };
    let t = Trainer::new(&dir, small_exp("mlp_tiny", 0.4)).unwrap();
    let space = graph::search_space_for(&t.engine.manifest.config).unwrap();
    let params = t.engine.init_params(0);
    let mut m = baselines::PruneThenPtq::new(
        t.exp.qasso.clone(),
        space.groups,
        t.engine.site_specs(),
        baselines::base_opt(&t.exp),
        &params,
        8.0,
        "HESSO+PTQ",
    );
    let r = t.run(&mut m).unwrap();
    // PTQ pins every site to 8 bits
    assert!((r.avg_bits - 8.0).abs() < 0.2, "bits {}", r.avg_bits);
    assert!(r.group_sparsity > 0.3);
}

#[test]
fn unstructured_baseline_density_accounting() {
    let Some(dir) = art() else { return };
    let t = Trainer::new(&dir, small_exp("mlp_tiny", 0.0)).unwrap();
    let steps = t.exp.total_steps();
    let mut m = baselines::UnstructuredJoint::new(
        0.5, 4.0, 16.0, baselines::base_opt(&t.exp), steps, "unstructured",
    );
    let r = t.run(&mut m).unwrap();
    // BOPs must reflect the 0.5 density even though no groups are pruned
    assert_eq!(r.group_sparsity, 0.0);
    assert!(r.rel_bops < 60.0, "rel bops {}", r.rel_bops);
}

#[test]
fn stage_ablation_variants_run() {
    let Some(dir) = art() else { return };
    let t = Trainer::new(&dir, small_exp("mlp_tiny", 0.4)).unwrap();
    for mask in [
        StageMask { warmup: false, ..Default::default() },
        StageMask { projection: false, ..Default::default() },
        StageMask { joint: false, ..Default::default() },
        StageMask { cooldown: false, ..Default::default() },
    ] {
        let mut g = GetaCompressor::new(&t.engine, &t.exp, mask).unwrap();
        let r = t.run(&mut g).unwrap();
        // sparsity target must hold even without the joint stage (one-shot
        // fallback) — the whole point of white-box control
        assert!(
            (r.group_sparsity - 0.4).abs() < 0.05,
            "mask {mask:?}: sparsity {}",
            r.group_sparsity
        );
    }
}

#[test]
fn seeds_change_data_but_not_contract() {
    let Some(dir) = art() else { return };
    let mut e1 = small_exp("mlp_tiny", 0.4);
    e1.seed = 11;
    let t = Trainer::new(&dir, e1).unwrap();
    let mut g = GetaCompressor::new(&t.engine, &t.exp, StageMask::default()).unwrap();
    let r = t.run(&mut g).unwrap();
    assert!((r.group_sparsity - 0.4).abs() < 0.02);
    assert!(r.accuracy > 50.0);
}
