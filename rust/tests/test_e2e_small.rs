//! End-to-end integration: full GETA runs (heavily step-scaled) plus the
//! sequential baseline. These are the contract tests for "all layers
//! compose".
//!
//! Backend selection is automatic: with AOT artifacts (and the `pjrt`
//! feature) the compiled-HLO engine runs; without them the native
//! interpreter serves **every** family — mlp, conv nets (vgg/resnet) and
//! transformers (bert/vit) all execute the warm-up → projection → joint →
//! cool-down pipeline on every machine. None of these tests may skip (see
//! `common::skip_or_panic`): a lowered family failing to build a backend
//! is a regression and panics.

mod common;

use common::art_dir;
use geta::baselines;
use geta::config::ExperimentConfig;
use geta::coordinator::{GetaCompressor, RunResult, Trainer};
use geta::graph;
use geta::optim::qasso::StageMask;
use geta::runtime::Backend as _;

/// Build a trainer; every zoo family has a native lowering, so failure is
/// always a bug (`skip_or_panic` panics for lowered families).
fn trainer(exp: ExperimentConfig) -> Trainer {
    let model = exp.model.clone();
    match Trainer::new(&art_dir(), exp) {
        Ok(t) => t,
        Err(e) => {
            common::skip_or_panic(&model, &e);
            panic!("{model} has a native lowering; skip_or_panic must not return");
        }
    }
}

fn small_exp(model: &str, sparsity: f64) -> ExperimentConfig {
    let mut e = ExperimentConfig::defaults_for(model);
    e.scale_steps(0.12);
    e.n_train = 256;
    e.n_eval = 128;
    e.qasso.target_group_sparsity = sparsity;
    e
}

/// One scaled-down GETA run; shared assertions for every family: the
/// sparsity target is hit, quantization + pruning produce a real
/// (nonzero, shape-derived) BOPs reduction, bits stay in [b_l, b_u], and
/// training neither diverges nor NaNs.
fn run_geta(t: &Trainer) -> RunResult {
    let mut g = GetaCompressor::new(&*t.engine, &t.exp, StageMask::default()).unwrap();
    let r = t.run(&mut g).unwrap();
    let target = t.exp.qasso.target_group_sparsity;
    assert!(
        (r.group_sparsity - target).abs() < 0.06,
        "{}: sparsity {} (target {target})",
        r.model,
        r.group_sparsity
    );
    assert!(
        r.rel_bops > 0.0 && r.rel_bops < 100.0,
        "{}: rel BOPs {} not a real reduction",
        r.model,
        r.rel_bops
    );
    assert!(
        r.avg_bits >= t.exp.qasso.b_l as f64 - 0.1 && r.avg_bits <= t.exp.qasso.b_u as f64 + 0.1,
        "{}: bits {}",
        r.model,
        r.avg_bits
    );
    assert!(r.trace.losses.iter().all(|l| l.is_finite()), "{}: loss NaN", r.model);
    assert!(
        r.final_loss < r.trace.losses[0] as f64 * 1.5 + 0.5,
        "{}: diverged {} -> {}",
        r.model,
        r.trace.losses[0],
        r.final_loss
    );
    r
}

#[test]
fn geta_mlp_learns_and_compresses() {
    let t = trainer(small_exp("mlp_tiny", 0.4));
    let r = run_geta(&t);
    assert!(r.accuracy > 60.0, "acc {}", r.accuracy);
    assert!((r.group_sparsity - 0.4).abs() < 0.02, "sparsity {}", r.group_sparsity);
    assert!(r.rel_bops < 60.0, "rel bops {}", r.rel_bops);
    // loss decreased over training
    assert!(r.final_loss < r.trace.losses[0] as f64, "no learning");
}

#[test]
fn geta_resnet_conv_pipeline() {
    // conv + batchnorm + residual adds + strided projections, end to end
    let t = trainer(small_exp("resnet_mini", 0.4));
    // "native" hermetically; "cpu" when the PJRT upgrade path is active
    assert!(
        ["cpu", "native"].contains(&t.engine.platform().as_str()),
        "{}",
        t.engine.platform()
    );
    let r = run_geta(&t);
    // quantized conv BOPs dominate: 32-bit init cools down into [4, 16]
    // bits, so the reduction must be substantial, not marginal
    assert!(r.rel_bops < 80.0, "rel bops {}", r.rel_bops);
    assert!(r.accuracy >= 0.0 && r.accuracy <= 100.0);
}

#[test]
fn geta_vgg_conv_pipeline() {
    // conv + maxpool + activation-quant sites (weight AND act quantized)
    let t = trainer(small_exp("vgg7_mini", 0.3));
    let r = run_geta(&t);
    assert!(r.rel_bops < 80.0, "rel bops {}", r.rel_bops);
}

#[test]
fn geta_vit_attention_pipeline() {
    // patch embed + cls token + multi-head attention + head-granular groups
    let t = trainer(small_exp("vit_mini", 0.3));
    let r = run_geta(&t);
    assert!(r.rel_bops < 90.0, "rel bops {}", r.rel_bops);
}

#[test]
fn geta_bert_span_task() {
    // never skipped anymore: the native interpreter lowers bert
    let t = trainer(small_exp("bert_mini", 0.3));
    let r = run_geta(&t);
    assert!(r.em.is_some() && r.f1.is_some());
    assert!(r.f1.unwrap() >= r.em.unwrap() - 1e-9); // F1 dominates EM
    assert!((r.group_sparsity - 0.3).abs() < 0.05);
}

#[test]
fn prune_then_ptq_baseline_runs() {
    let t = trainer(small_exp("mlp_tiny", 0.4));
    let space = graph::search_space_for(&t.engine.manifest().config).unwrap();
    let params = t.engine.init_params(0);
    let mut m = baselines::PruneThenPtq::new(
        t.exp.qasso.clone(),
        space.groups,
        t.engine.site_specs(),
        baselines::base_opt(&t.exp),
        &params,
        8.0,
        "HESSO+PTQ",
    );
    let r = t.run(&mut m).unwrap();
    // PTQ pins every site to 8 bits
    assert!((r.avg_bits - 8.0).abs() < 0.2, "bits {}", r.avg_bits);
    assert!(r.group_sparsity > 0.3);
}

#[test]
fn unstructured_baseline_density_accounting() {
    let t = trainer(small_exp("mlp_tiny", 0.0));
    let steps = t.exp.total_steps();
    let mut m = baselines::UnstructuredJoint::new(
        0.5, 4.0, 16.0, baselines::base_opt(&t.exp), steps, "unstructured",
    );
    let r = t.run(&mut m).unwrap();
    // BOPs must reflect the 0.5 density even though no groups are pruned
    assert_eq!(r.group_sparsity, 0.0);
    assert!(r.rel_bops < 60.0, "rel bops {}", r.rel_bops);
}

#[test]
fn stage_ablation_variants_run() {
    let t = trainer(small_exp("mlp_tiny", 0.4));
    for mask in [
        StageMask { warmup: false, ..Default::default() },
        StageMask { projection: false, ..Default::default() },
        StageMask { joint: false, ..Default::default() },
        StageMask { cooldown: false, ..Default::default() },
    ] {
        let mut g = GetaCompressor::new(&*t.engine, &t.exp, mask).unwrap();
        let r = t.run(&mut g).unwrap();
        // sparsity target must hold even without the joint stage (one-shot
        // fallback) — the whole point of white-box control
        assert!(
            (r.group_sparsity - 0.4).abs() < 0.05,
            "mask {mask:?}: sparsity {}",
            r.group_sparsity
        );
    }
}

#[test]
fn seeds_change_data_but_not_contract() {
    let mut e1 = small_exp("mlp_tiny", 0.4);
    e1.seed = 11;
    let t = trainer(e1);
    let mut g = GetaCompressor::new(&*t.engine, &t.exp, StageMask::default()).unwrap();
    let r = t.run(&mut g).unwrap();
    assert!((r.group_sparsity - 0.4).abs() < 0.02);
    assert!(r.accuracy > 50.0);
}
