//! Planned-executor integration tests: the thread-count determinism
//! contract of the shared training/deployment forward core.
//!
//! The tiled GEMM kernels (`tensor/ops.rs`) partition output rows across
//! `GETA_THREADS` workers with a partition-independent accumulation
//! order, so *everything downstream* — training loss curves, gradients,
//! eval logits, deployed inference — must be bit-identical at any worker
//! count. These tests pin that end to end; the per-kernel property tests
//! live next to the kernels.

mod common;

use common::art_dir;
use geta::config::ExperimentConfig;
use geta::coordinator::Trainer;
use geta::runtime::Backend as _;
use geta::tensor;

/// A short SGD run: the per-step loss curve and the final eval logits.
fn short_run(model: &str, threads: usize) -> (Vec<f32>, Vec<f32>) {
    tensor::set_threads(threads);
    let exp = ExperimentConfig::defaults_for(model);
    let t = Trainer::new(&art_dir(), exp).unwrap();
    let mut params = t.engine.init_params(3);
    let q = t.engine.init_qparams(&params, 8.0);
    let idxs: Vec<usize> = (0..t.batch_size()).collect();
    let (x, y) = t.train_data.batch(&idxs);
    let mut losses = Vec::new();
    for _ in 0..4 {
        let out = t.engine.train_step(&params, &q, &x, &y).unwrap();
        losses.push(out.loss);
        for (ti, g) in out.grads.tensors.iter().enumerate() {
            for (i, gv) in g.data.iter().enumerate() {
                params.tensors[ti].data[i] -= 0.05 * gv;
            }
        }
    }
    let (ex, ey) = t.eval_data.batch(&idxs);
    let logits = t.engine.eval_logits(&params, &q, &ex, &ey).unwrap();
    (losses, logits)
}

#[test]
fn training_and_logits_are_bit_identical_across_thread_counts() {
    // mlp + resnet e2e at 1 vs 4 worker threads: loss curves and logits
    // must agree to the last bit (== on f32, no tolerance)
    let prev = tensor::configured_threads();
    for model in ["mlp_tiny", "resnet_mini"] {
        let (l1, g1) = short_run(model, 1);
        let (l4, g4) = short_run(model, 4);
        assert_eq!(l1, l4, "{model}: training loss curve changed with thread count");
        assert!(!g1.is_empty(), "{model}: no logits");
        assert_eq!(g1, g4, "{model}: eval logits changed with thread count");
    }
    tensor::set_threads(prev);
}

#[test]
fn repeated_steps_reuse_the_engine_arena() {
    // same engine, same inputs, many steps: the arena recycles buffers
    // across steps, which must never change results
    let exp = ExperimentConfig::defaults_for("vgg7_mini");
    let t = Trainer::new(&art_dir(), exp).unwrap();
    let params = t.engine.init_params(5);
    let q = t.engine.init_qparams(&params, 8.0);
    let idxs: Vec<usize> = (0..t.batch_size()).collect();
    let (x, y) = t.train_data.batch(&idxs);
    let first = t.engine.train_step(&params, &q, &x, &y).unwrap();
    for _ in 0..3 {
        let again = t.engine.train_step(&params, &q, &x, &y).unwrap();
        assert_eq!(first.loss, again.loss, "arena reuse changed the loss");
        for (a, b) in first.grads.tensors.iter().zip(&again.grads.tensors) {
            assert_eq!(a.data, b.data, "arena reuse changed gradient {}", a.name);
        }
    }
    // interleave an eval pass (different buffer shapes through the same
    // arena), then train again: still identical
    t.engine.eval_step(&params, &q, &x, &y).unwrap();
    let after = t.engine.train_step(&params, &q, &x, &y).unwrap();
    assert_eq!(first.loss, after.loss);
}
