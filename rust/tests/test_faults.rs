//! Fault-tolerance obligations (`geta::serve` under an armed
//! [`FaultPlan`]):
//!
//! 1. **Typed per-request failure** — under every injected fault class
//!    the victim fails with the matching `ServeError` variant; its
//!    batchmates are unaffected.
//! 2. **Survivor parity** — every request that completes under a fault
//!    storm returns logits bitwise identical to a fault-free run.
//! 3. **Supervision** — a model-call panic retires the worker thread and
//!    a respawn takes its place; the server keeps serving and shuts down
//!    with zero dead workers.
//! 4. **Deadlines** — requests whose deadline passes in-queue fail typed
//!    with `DeadlineExceeded` without spending a model call.
//! 5. **No ticket leaks** — every accepted request resolves (reply or
//!    typed error), pinned by the chaos soak's `unresolved == 0`.
//! 6. **Determinism** — same seed, same spec, same request count ⇒
//!    byte-identical `ChaosReport`, the contract CI's chaos-smoke job
//!    byte-diffs on.
//!
//! Fault marking is a pure function of `(seed, arrival index)`, so the
//! tests *derive* the expected outcome of each request from
//! `FaultPlan::fault_for` instead of hard-coding counts; seeds are
//! searched (cheaply, over the pure function) until the classes a test
//! needs are all represented.

mod common;

use std::sync::{Arc, OnceLock};
use std::time::Duration;

use common::art_dir;
use geta::deploy::{GetaContainer, GetaEngine, KernelKind};
use geta::runtime::HostArray;
use geta::serve::loadgen::Backoff;
use geta::serve::{
    faults, BatchModel, FaultKind, FaultPlan, FaultSpec, ModelCache, Priority, ServeConfig,
    ServeError, Server,
};

struct Setup {
    container: GetaContainer,
    singles: Vec<HostArray>,
}

fn setup() -> &'static Setup {
    static CELL: OnceLock<Setup> = OnceLock::new();
    CELL.get_or_init(|| {
        let art = geta::report::train_export(&art_dir(), "mlp_tiny", 0.1, 0.5, 8.0)
            .expect("mlp_tiny trains natively");
        let singles = geta::serve::loadgen::single_sample_inputs(&art.trainer.eval_data, 8);
        Setup {
            container: art.container,
            singles,
        }
    })
}

fn engine() -> Arc<GetaEngine> {
    let mut e = GetaEngine::from_container_kernel(&setup().container, KernelKind::Int8)
        .expect("container round-trips");
    e.threads = 1;
    Arc::new(e)
}

/// First seed whose plan marks at least one request of every kind in
/// `need` — and leaves at least one request unmarked — within the first
/// `n` arrival indices. Pure-function search: no server involved.
fn seed_with(spec: FaultSpec, n: u64, need: &[FaultKind]) -> u64 {
    (0..10_000u64)
        .find(|&s| {
            let plan = FaultPlan::new(s, spec);
            let marks: Vec<_> = (0..n).map(|i| plan.fault_for(i)).collect();
            need.iter().all(|k| marks.contains(&Some(*k))) && marks.contains(&None)
        })
        .expect("a seed covering every needed class exists")
}

// ---------------------------------------------------------------- 1 + 2 + 3
#[test]
fn injected_faults_fail_typed_and_survivors_stay_bitwise_intact() {
    let s = setup();
    let e = engine();
    let n = 24u64;
    let spec = FaultSpec::parse("panic:0.2,poison:0.2,err:0.2").unwrap();
    let seed = seed_with(
        spec,
        n,
        &[FaultKind::Panic, FaultKind::Poison, FaultKind::Transient],
    );
    let plan = Arc::new(FaultPlan::new(seed, spec));
    let marks: Vec<Option<FaultKind>> = (0..n).map(|i| plan.fault_for(i)).collect();
    let n_panic = marks.iter().filter(|m| **m == Some(FaultKind::Panic)).count();
    let n_poison = marks.iter().filter(|m| **m == Some(FaultKind::Poison)).count();

    let direct: Vec<Vec<f32>> = s.singles.iter().map(|x| e.infer(x).unwrap()).collect();
    let server = Server::start_faulted(
        e,
        ServeConfig {
            workers: 2,
            queue_depth: 64,
            batch_window: Duration::from_micros(300),
            max_batch: 4,
        },
        Some(Arc::clone(&plan)),
    );
    // one submitter thread ⇒ arrival indices equal submission order
    let tickets: Vec<_> = (0..n as usize)
        .map(|i| {
            let x = s.singles[i % s.singles.len()].clone();
            (i, server.submit(x).expect("queue has room"))
        })
        .collect();
    for (i, t) in tickets {
        let outcome = t.wait_typed();
        match marks[i] {
            Some(FaultKind::Panic) => {
                let err = outcome.expect_err("panic-marked request must fail");
                assert!(
                    matches!(err, ServeError::WorkerPanic { .. }),
                    "request {i}: expected WorkerPanic, got {err:?}"
                );
            }
            Some(FaultKind::Poison) => {
                let err = outcome.expect_err("poisoned request must fail");
                match &err {
                    ServeError::Model { msg } => assert!(
                        msg.contains("model expects"),
                        "request {i}: poison must surface the engine's input validation: {msg}"
                    ),
                    other => panic!("request {i}: expected Model error, got {other:?}"),
                }
            }
            // Slow completes late, Transient recovers, unmarked just works —
            // and all of them must match the fault-free logits bit for bit.
            _ => {
                let reply = outcome.unwrap_or_else(|e| panic!("request {i} failed: {e:?}"));
                let want = &direct[i % s.singles.len()];
                assert_eq!(reply.logits.len(), want.len());
                assert!(
                    reply.logits.iter().zip(want).all(|(a, b)| a.to_bits() == b.to_bits()),
                    "request {i}: survivor logits drifted under the fault storm"
                );
            }
        }
    }
    let report = server.shutdown();
    assert_eq!(report.stats.accepted, n);
    assert_eq!(report.stats.completed, n, "every ticket answered by a worker");
    assert_eq!(report.stats.failed, (n_panic + n_poison) as u64);
    assert_eq!(report.stats.expired, 0);
    assert!(report.stats.worker_panics >= n_panic as u64);
    assert!(
        report.stats.worker_restarts >= 1,
        "a caught panic must retire and respawn the worker"
    );
    assert_eq!(report.dead_workers, 0, "supervised workers exit cleanly");
    let [inj_panic, _, inj_poison, _] = plan.injected();
    assert_eq!(inj_panic as usize, n_panic);
    assert_eq!(inj_poison as usize, n_poison);
    // failures never enter the latency histogram
    assert_eq!(
        report.histogram.count(),
        n - (n_panic + n_poison) as u64
    );
}

// ---------------------------------------------------------------- transient
#[test]
fn transient_errors_recover_via_one_bounded_retry() {
    let s = setup();
    let e = engine();
    let direct: Vec<Vec<f32>> = s.singles.iter().map(|x| e.infer(x).unwrap()).collect();
    let n = 10usize;
    // every request transient, max_batch 1 ⇒ every first call errs and
    // every retry succeeds: exactly n retries, zero failures
    let plan = Arc::new(FaultPlan::new(5, FaultSpec::parse("err:1.0").unwrap()));
    let server = Server::start_faulted(
        e,
        ServeConfig {
            workers: 1,
            queue_depth: 64,
            batch_window: Duration::ZERO,
            max_batch: 1,
        },
        Some(plan),
    );
    let tickets: Vec<_> = (0..n)
        .map(|i| server.submit(s.singles[i % s.singles.len()].clone()).unwrap())
        .collect();
    for (i, t) in tickets.into_iter().enumerate() {
        let reply = t.wait_typed().expect("transient faults must recover");
        let want = &direct[i % s.singles.len()];
        assert!(
            reply.logits.iter().zip(want).all(|(a, b)| a.to_bits() == b.to_bits()),
            "request {i}: retried logits drifted"
        );
    }
    let report = server.shutdown();
    assert_eq!(report.stats.retries, n as u64);
    assert_eq!(report.stats.failed, 0);
    assert_eq!(report.stats.completed, n as u64);
    assert_eq!(report.stats.worker_restarts, 0, "errors are not panics");
}

/// Deliberately slow model (same double as test_serve.rs): makes queue
/// occupancy deterministic.
struct SleepyModel {
    delay: Duration,
}

impl BatchModel for SleepyModel {
    fn infer_many(&self, xs: &[&HostArray]) -> anyhow::Result<Vec<Vec<f32>>> {
        std::thread::sleep(self.delay);
        Ok(xs.iter().map(|x| vec![x.len() as f32]).collect())
    }
}

fn tiny_request() -> HostArray {
    HostArray::F32(vec![1.0, 2.0])
}

/// Block until the queue is empty (the busy request was picked up).
fn wait_queue_empty(server: &Server) {
    for _ in 0..2000 {
        if server.queued() == 0 {
            return;
        }
        std::thread::sleep(Duration::from_millis(1));
    }
    panic!("queue never drained to the worker");
}

// ---------------------------------------------------------------- 4
#[test]
fn deadlines_expire_queued_requests_typed_without_a_model_call() {
    let server = Server::start(
        Arc::new(SleepyModel {
            delay: Duration::from_millis(40),
        }),
        ServeConfig {
            workers: 1,
            queue_depth: 64,
            batch_window: Duration::ZERO,
            max_batch: 1,
        },
    );
    // occupy the single worker for 40ms…
    let busy = server.submit(tiny_request()).unwrap();
    wait_queue_empty(&server);
    // …then queue requests that can only expire behind it
    let k = 4usize;
    let doomed: Vec<_> = (0..k)
        .map(|_| {
            server
                .submit_with(tiny_request(), Priority::Normal, Some(Duration::from_millis(1)))
                .unwrap()
        })
        .collect();
    std::thread::sleep(Duration::from_millis(10)); // all k are now past-deadline
    for t in doomed {
        match t.wait_typed() {
            Err(ServeError::DeadlineExceeded { waited_us }) => {
                assert!(waited_us >= 1_000, "must report at least the 1ms deadline, got {waited_us}us");
            }
            other => panic!("expected DeadlineExceeded, got {other:?}"),
        }
    }
    busy.wait_typed().expect("no-deadline request completes");
    // expiry cost no model call and the server is still live
    let probe = server.submit(tiny_request()).unwrap();
    probe.wait_typed().expect("server live after expiries");
    let report = server.shutdown();
    assert_eq!(report.stats.expired, k as u64);
    assert_eq!(report.stats.completed, 2);
    assert_eq!(report.stats.failed, 0, "expiry is not a worker failure");
    assert_eq!(
        report.stats.accepted,
        report.stats.completed + report.stats.expired,
        "accounting must close: accepted == completed + expired"
    );
    assert_eq!(report.stats.batches, 2, "expired requests never reach infer_many");
    assert_eq!(report.histogram.count(), 2);
}

// ---------------------------------------------------------------- priority
#[test]
fn high_priority_lane_is_served_before_older_low_priority_work() {
    let server = Server::start(
        Arc::new(SleepyModel {
            delay: Duration::from_millis(20),
        }),
        ServeConfig {
            workers: 1,
            queue_depth: 64,
            batch_window: Duration::ZERO,
            max_batch: 1,
        },
    );
    let busy = server.submit(tiny_request()).unwrap();
    wait_queue_empty(&server);
    // two Low requests enqueued *before* one High
    let lows: Vec<_> = (0..2)
        .map(|_| server.submit_with(tiny_request(), Priority::Low, None).unwrap())
        .collect();
    let high = server.submit_with(tiny_request(), Priority::High, None).unwrap();
    let h = high.wait_typed().expect("high-priority served");
    let low_replies: Vec<_> = lows
        .into_iter()
        .map(|t| t.wait_typed().expect("low-priority served eventually"))
        .collect();
    // High was submitted last (shortest possible wait) yet served first
    // (earliest completion): its measured latency must undercut both Low
    // latencies by at least one 20ms service slot.
    for (i, l) in low_replies.iter().enumerate() {
        assert!(
            h.latency < l.latency,
            "lane order violated: high latency {:?} !< low[{i}] latency {:?}",
            h.latency,
            l.latency
        );
    }
    busy.wait_typed().unwrap();
    server.shutdown();
}

// ---------------------------------------------------------------- 5
#[test]
fn chaos_soak_leaks_no_tickets_and_accounts_every_outcome() {
    let s = setup();
    let e = engine();
    let requests = 120usize;
    let spec = FaultSpec::parse("panic:0.1,slow:0.05:500,poison:0.1,err:0.15").unwrap();
    let seed = seed_with(
        spec,
        requests as u64,
        &[
            FaultKind::Panic,
            FaultKind::Slow,
            FaultKind::Poison,
            FaultKind::Transient,
        ],
    );
    let plan = Arc::new(FaultPlan::new(seed, spec));
    // expected marks from a twin plan (pure function of seed + index)
    let twin = FaultPlan::new(seed, spec);
    let marks: Vec<_> = (0..requests as u64).map(|i| twin.fault_for(i)).collect();
    let count = |k: FaultKind| marks.iter().filter(|m| **m == Some(k)).count();
    let (n_panic, n_slow, n_poison, n_transient) = (
        count(FaultKind::Panic),
        count(FaultKind::Slow),
        count(FaultKind::Poison),
        count(FaultKind::Transient),
    );
    let expected: Vec<Vec<f32>> = s.singles.iter().map(|x| e.infer(x).unwrap()).collect();
    let chaos = faults::chaos_soak(
        e,
        &s.singles,
        &expected,
        ServeConfig {
            workers: 2,
            queue_depth: 16,
            batch_window: Duration::from_micros(200),
            max_batch: 4,
        },
        plan,
        requests,
        3,
    );
    assert_eq!(chaos.unresolved, 0, "no ticket may leak");
    assert_eq!(chaos.mismatched_logits, 0, "survivors must be bitwise intact");
    assert_eq!(chaos.failed_other, 0);
    assert_eq!(chaos.failed_deadline, 0, "no deadlines were set");
    assert!(chaos.server_live_after, "server must answer after the storm");
    // the soak's outcome is *exactly* determined by the marking
    assert_eq!(chaos.injected_panic as usize, n_panic);
    assert_eq!(chaos.injected_slow as usize, n_slow);
    assert_eq!(chaos.injected_poison as usize, n_poison);
    assert_eq!(chaos.injected_transient as usize, n_transient);
    assert_eq!(chaos.failed_worker_panic, n_panic);
    assert_eq!(chaos.failed_model, n_poison);
    assert_eq!(
        chaos.completed,
        requests - n_panic - n_poison,
        "slow + transient + unmarked all complete"
    );
    assert!(chaos.worker_restarts_positive, "panics must drive respawns");
}

/// Cheap deterministic model with engine-like input validation: doubles as
/// the fault-free reference for the determinism soak (the real engine is
/// exercised by the soak above; this one pins byte-level repeatability).
struct StrictModel;

impl BatchModel for StrictModel {
    fn infer_many(&self, xs: &[&HostArray]) -> anyhow::Result<Vec<Vec<f32>>> {
        xs.iter()
            .enumerate()
            .map(|(r, x)| match x {
                HostArray::F32(v) => Ok(vec![v.iter().sum::<f32>(), v.len() as f32]),
                HostArray::I32(_) => anyhow::bail!("request {r}: model expects F32 inputs"),
            })
            .collect()
    }
}

// ---------------------------------------------------------------- 6
#[test]
fn same_seed_chaos_soaks_produce_identical_reports() {
    let inputs = vec![
        HostArray::F32(vec![1.0, 2.0, 3.0]),
        HostArray::F32(vec![0.5, -1.5]),
    ];
    let expected: Vec<Vec<f32>> = inputs
        .iter()
        .map(|x| match x {
            HostArray::F32(v) => vec![v.iter().sum::<f32>(), v.len() as f32],
            HostArray::I32(_) => unreachable!(),
        })
        .collect();
    let spec = FaultSpec::parse("panic:0.1,slow:0.05:200,poison:0.1,err:0.1").unwrap();
    let seed = seed_with(
        spec,
        60,
        &[FaultKind::Panic, FaultKind::Poison, FaultKind::Transient],
    );
    let cfg = ServeConfig {
        workers: 2,
        queue_depth: 8,
        batch_window: Duration::from_micros(100),
        max_batch: 4,
    };
    let run = || {
        faults::chaos_soak(
            Arc::new(StrictModel),
            &inputs,
            &expected,
            cfg.clone(),
            Arc::new(FaultPlan::new(seed, spec)),
            60,
            2,
        )
    };
    let (a, b) = (run(), run());
    assert_eq!(a, b, "same seed + spec + requests must reproduce exactly");
    assert_eq!(a.unresolved, 0);
    assert_eq!(a.mismatched_logits, 0);
    assert!(a.injected_panic > 0 && a.injected_poison > 0 && a.injected_transient > 0);
    assert_eq!(
        a.completed,
        60 - a.failed_worker_panic - a.failed_model,
        "accounting closes in both runs"
    );
}

// ---------------------------------------------------------------- cache
#[test]
fn model_cache_never_caches_failed_loads_and_evicts_cleanly() {
    let s = setup();
    let path = std::env::temp_dir().join("geta_test_faults_cache.geta");
    let key = path.display().to_string();
    let cache = ModelCache::new(KernelKind::Int8);
    // a torn/garbage artifact must fail the load *and leave no entry*
    std::fs::write(&path, b"definitely not a geta container").unwrap();
    assert!(cache.get_or_load(&path).is_err());
    assert_eq!(cache.len(), 0, "failed loads are never cached");
    // the moment a valid artifact lands on the same path, it serves —
    // no restart, no stale negative entry
    std::fs::write(&path, s.container.to_bytes()).unwrap();
    let a = cache.get_or_load(&path).expect("repaired artifact loads");
    assert_eq!(cache.len(), 1);
    // eviction drops the entry but never an in-flight Arc
    let evicted = cache.evict(&key).expect("entry was cached");
    assert!(Arc::ptr_eq(&a, &evicted));
    assert!(cache.is_empty());
    assert!(a.infer(&s.singles[0]).is_ok(), "evicted engines still serve holders");
    assert!(cache.evict(&key).is_none(), "double evict is a no-op");
    let b = cache.get_or_load(&path).expect("reload after evict");
    assert!(!Arc::ptr_eq(&a, &b), "evict forces a fresh load");
    std::fs::remove_file(&path).ok();
}

// ---------------------------------------------------------------- backoff
#[test]
fn backoff_is_deterministic_bounded_and_resettable() {
    let mut a = Backoff::new(123);
    let mut b = Backoff::new(123);
    let seq_a: Vec<Duration> = (0..12).map(|_| a.pause()).collect();
    let seq_b: Vec<Duration> = (0..12).map(|_| b.pause()).collect();
    assert_eq!(seq_a, seq_b, "same seed ⇒ same jittered pause sequence");
    let max = Duration::from_micros(5_000);
    for (i, p) in seq_a.iter().enumerate() {
        assert!(*p > Duration::ZERO, "pause {i} must actually pause");
        assert!(*p <= max, "pause {i} = {p:?} exceeds the ladder cap");
    }
    // the ladder grows: late pauses sit near the cap
    assert!(seq_a[11] >= Duration::from_micros(2_500));
    // different seeds jitter differently (with overwhelming probability)
    let mut c = Backoff::new(77);
    let seq_c: Vec<Duration> = (0..12).map(|_| c.pause()).collect();
    assert_ne!(seq_a, seq_c, "jitter streams must be seed-dependent");
    // an admission resets the ladder to the base pause
    a.reset();
    assert!(a.pause() <= Duration::from_micros(50));
}
