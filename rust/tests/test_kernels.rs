//! Differential kernel-equivalence harness (tier-1).
//!
//! Locks down the contracts the tensor kernels advertise, across every
//! compute path the deployment engine can select:
//!
//! * tiled (and, with `--features simd`, vectorized) f32 GEMMs agree with
//!   the naive triple-loop references to ≤ 1e-6 relative, and are bitwise
//!   identical across thread counts;
//! * the integer GEMMs (i8×i8 and nibble-packed u4) agree with their
//!   naive references **exactly** — i32 accumulation is associative, so
//!   there is no tolerance to hide behind — at every thread count;
//! * the scaled epilogues (i8, f32×i8, u4) match an in-test f64 reference;
//! * the i32-overflow admission gate `i8_gemm_fits_i32` is exact at the
//!   boundary: the largest admitted contraction really fits, with
//!   saturating ±127 (and ±7 for u4) inputs;
//! * nibble pack/unpack round-trips every sub-byte width incl. odd tails.
//!
//! The shape sweep is deliberately adversarial: k = 0, k = 1, single
//! row/column outputs, and dims that are not multiples of any tile or
//! SIMD lane width (4/8/16). The whole suite must stay green with the
//! `simd` feature on and off — that equivalence is the feature's safety
//! argument (see rust/src/tensor/README.md).

use std::sync::Mutex;

use geta::tensor::{
    configured_threads, i8_gemm_fits_i32, matmul, matmul_i8, matmul_i8_naive,
    matmul_i8_scaled_into, matmul_f32i8_scaled_into, matmul_naive, matmul_nt, matmul_nt_naive,
    matmul_tn, matmul_tn_naive, matmul_u4, matmul_u4_naive, pack_nibbles, set_threads,
    unpack_nibbles, U4Weight,
};
use geta::util::rng::Rng;

/// Serializes every test that mutates the process-wide thread budget
/// (tests in one binary run concurrently). The library's own lock is
/// crate-private, so this harness keeps its own.
static THREADS_LOCK: Mutex<()> = Mutex::new(());

/// Shape sweep: (m, k, n). Covers empty contraction, unit dims, exact
/// tile multiples (TILE_I = 16), and dims coprime to the 4/8/16-wide
/// unrolls and SIMD lanes.
const SHAPES: &[(usize, usize, usize)] = &[
    (1, 0, 1),
    (3, 0, 5),
    (1, 1, 1),
    (2, 1, 3),
    (1, 7, 17),
    (5, 3, 1),
    (16, 256, 16),
    (17, 33, 9),
    (33, 257, 31),
    (4, 512, 40),
    (65, 19, 23),
];

const THREAD_COUNTS: &[usize] = &[1, 2, 4];

fn assert_close(got: &[f32], want: &[f32], tol: f32, what: &str) {
    assert_eq!(got.len(), want.len(), "{what}: length");
    for (i, (&g, &w)) in got.iter().zip(want).enumerate() {
        assert!(
            (g - w).abs() <= tol * (1.0 + w.abs()),
            "{what}[{i}]: got {g}, want {w}"
        );
    }
}

/// Random f32 buffer with exact zeros sprinkled in, so the kernels'
/// zero-skip fast paths run in both taken and not-taken flavors.
fn rand_f32(rng: &mut Rng, len: usize) -> Vec<f32> {
    let mut v = vec![0.0f32; len];
    rng.fill_normal(&mut v, 1.0);
    for x in v.iter_mut() {
        if rng.below(4) == 0 {
            *x = 0.0;
        }
    }
    v
}

/// Random i8 levels in `-lmax..=lmax`, zeros included.
fn rand_i8(rng: &mut Rng, len: usize, lmax: i32) -> Vec<i8> {
    (0..len)
        .map(|_| (rng.below((2 * lmax + 1) as usize) as i32 - lmax) as i8)
        .collect()
}

#[test]
fn f32_gemms_match_naive_and_are_bitwise_thread_invariant() {
    let _g = THREADS_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let prev = configured_threads();
    let mut rng = Rng::new(0x51D_0001);
    for &(m, k, n) in SHAPES {
        let a = rand_f32(&mut rng, m * k);
        let b_kn = rand_f32(&mut rng, k * n); // matmul:    a[m,k] @ b[k,n]
        let b_mn = rand_f32(&mut rng, m * n); // matmul_tn: a[m,k]^T @ b[m,n]
        let b_nk = rand_f32(&mut rng, n * k); // matmul_nt: a[m,k] @ b[n,k]^T
        let want = matmul_naive(&a, &b_kn, m, k, n);
        let want_tn = matmul_tn_naive(&a, &b_mn, m, k, n);
        let want_nt = matmul_nt_naive(&a, &b_nk, m, k, n);
        let mut base: Option<(Vec<f32>, Vec<f32>, Vec<f32>)> = None;
        for &t in THREAD_COUNTS {
            set_threads(t);
            let got = matmul(&a, &b_kn, m, k, n);
            let got_tn = matmul_tn(&a, &b_mn, m, k, n);
            let got_nt = matmul_nt(&a, &b_nk, m, k, n);
            let what = format!("({m},{k},{n}) threads={t}");
            assert_close(&got, &want, 1e-6, &format!("matmul {what}"));
            assert_close(&got_tn, &want_tn, 1e-6, &format!("matmul_tn {what}"));
            assert_close(&got_nt, &want_nt, 1e-6, &format!("matmul_nt {what}"));
            match &base {
                None => base = Some((got, got_tn, got_nt)),
                Some((b0, b1, b2)) => {
                    // bitwise: thread partitioning must not move a ulp
                    let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<u32>>();
                    assert_eq!(bits(&got), bits(b0), "matmul {what} vs threads=1");
                    assert_eq!(bits(&got_tn), bits(b1), "matmul_tn {what} vs threads=1");
                    assert_eq!(bits(&got_nt), bits(b2), "matmul_nt {what} vs threads=1");
                }
            }
        }
    }
    set_threads(prev);
}

#[test]
fn i8_gemm_matches_naive_exactly_across_threads() {
    let _g = THREADS_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let prev = configured_threads();
    let mut rng = Rng::new(0x51D_0002);
    for &(m, k, n) in SHAPES {
        let a = rand_i8(&mut rng, m * k, 127);
        let b = rand_i8(&mut rng, k * n, 127);
        let want = matmul_i8_naive(&a, &b, m, k, n);
        for &t in THREAD_COUNTS {
            set_threads(t);
            let got = matmul_i8(&a, &b, m, k, n);
            assert_eq!(got, want, "matmul_i8 ({m},{k},{n}) threads={t}");
        }
    }
    set_threads(prev);
}

#[test]
fn u4_gemm_matches_naive_exactly_across_threads() {
    let _g = THREADS_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let prev = configured_threads();
    let mut rng = Rng::new(0x51D_0003);
    for &(m, k, n) in SHAPES {
        let levels: Vec<i32> =
            (0..k * n).map(|_| rng.below(15) as i32 - 7).collect();
        let w = U4Weight::from_levels(&levels, n, 0.01).expect("levels fit a nibble");
        assert_eq!((w.k, w.n), (k, n));
        let a = rand_i8(&mut rng, m * k, 127);
        let want = matmul_u4_naive(&a, &w, m);
        for &t in THREAD_COUNTS {
            set_threads(t);
            let got = matmul_u4(&a, &w, m);
            assert_eq!(got, want, "matmul_u4 ({m},{k},{n}) threads={t}");
        }
    }
    set_threads(prev);
}

#[test]
fn scaled_epilogues_match_f64_reference_at_every_thread_count() {
    let _g = THREADS_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let prev = configured_threads();
    let mut rng = Rng::new(0x51D_0004);
    for &(m, k, n) in &[(9usize, 31usize, 14usize), (17, 64, 5), (1, 1, 1), (2, 0, 3)] {
        let la = rand_i8(&mut rng, m * k, 25);
        let lb = rand_i8(&mut rng, k * n, 127);
        let xa = rand_f32(&mut rng, m * k);
        let scale: Vec<f32> = (0..n).map(|j| 2e-3 + j as f32 * 1e-4).collect();
        let bias = rand_f32(&mut rng, n);
        let alpha = 3e-3f32;
        // f64 references, computed independently of any tiling
        let mut want_int = vec![0.0f32; m * n];
        let mut want_mixed = vec![0.0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0i64;
                let mut facc = 0.0f64;
                for kk in 0..k {
                    acc += la[i * k + kk] as i64 * lb[kk * n + j] as i64;
                    facc += xa[i * k + kk] as f64 * lb[kk * n + j] as f64;
                }
                want_int[i * n + j] =
                    (acc as f64 * (alpha as f64 * scale[j] as f64) + bias[j] as f64) as f32;
                want_mixed[i * n + j] = (facc * scale[j] as f64 + bias[j] as f64) as f32;
            }
        }
        for &t in THREAD_COUNTS {
            set_threads(t);
            let what = format!("({m},{k},{n}) threads={t}");
            let mut got = vec![0.0f32; m * n];
            matmul_i8_scaled_into(&mut got, &la, &lb, m, k, n, &scale, alpha, Some(&bias));
            // exact integer sum + one shared f64 epilogue: bitwise
            let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<u32>>();
            assert_eq!(bits(&got), bits(&want_int), "matmul_i8_scaled_into {what}");
            let mut got = vec![0.0f32; m * n];
            matmul_f32i8_scaled_into(&mut got, &xa, &lb, m, k, n, &scale, Some(&bias));
            // f64 accumulation differs from the reference only in order
            assert_close(&got, &want_mixed, 1e-6, &format!("matmul_f32i8_scaled_into {what}"));
        }
    }
    set_threads(prev);
}

#[test]
fn i8_overflow_gate_is_exact_at_the_boundary() {
    // largest contraction the gate admits at saturating ±127 inputs
    let kfit = i32::MAX as usize / (127 * 127);
    assert!(i8_gemm_fits_i32(kfit, 127, 127));
    assert!(!i8_gemm_fits_i32(kfit + 1, 127, 127));
    // run it for real: every product is +127·127, the true sum must land
    // in the i32 accumulator with no wraparound
    let a = vec![127i8; kfit];
    let b = vec![127i8; kfit]; // n = 1 column
    let got = matmul_i8(&a, &b, 1, kfit, 1);
    assert_eq!(got[0] as i64, kfit as i64 * 127 * 127);
    // mixed signs at the same length stay exact too
    let mut a2 = a;
    for (i, v) in a2.iter_mut().enumerate() {
        if i % 2 == 0 {
            *v = -127;
        }
    }
    let want: i64 = a2.iter().map(|&x| x as i64 * 127).sum();
    assert_eq!(matmul_i8(&a2, &b, 1, kfit, 1)[0] as i64, want);
    // degenerate corners of the gate itself
    assert!(i8_gemm_fits_i32(0, 127, 127));
    assert!(i8_gemm_fits_i32(1, 127, 127));
}

#[test]
fn u4_overflow_gate_is_exact_at_the_boundary() {
    // u4 weights bound |w| by 7, so the admitted contraction is far longer
    let kfit = i32::MAX as usize / (127 * 7);
    assert!(i8_gemm_fits_i32(kfit, 127, 7));
    assert!(!i8_gemm_fits_i32(kfit + 1, 127, 7));
    let a = vec![127i8; kfit];
    let w = U4Weight::from_levels(&vec![7i32; kfit], 1, 1.0).expect("±7 fits a nibble");
    assert_eq!(w.max_abs, 7);
    let got = matmul_u4(&a, &w, 1);
    assert_eq!(got[0] as i64, kfit as i64 * 127 * 7);
}

#[test]
fn u4_from_levels_enforces_the_nibble_range() {
    // -7..=7 is in; ±8 (the asymmetric two's-complement corner) is out
    assert!(U4Weight::from_levels(&[-7, 0, 7, 3], 2, 0.1).is_some());
    assert!(U4Weight::from_levels(&[-8, 0, 7, 3], 2, 0.1).is_none());
    assert!(U4Weight::from_levels(&[8, 0, 7, 3], 2, 0.1).is_none());
    // ragged shapes are rejected, not truncated
    assert!(U4Weight::from_levels(&[1, 2, 3], 2, 0.1).is_none());
    assert!(U4Weight::from_levels(&[], 3, 0.1).is_some()); // k = 0 is fine
}

#[test]
fn nibble_pack_unpack_roundtrips_all_subbyte_widths_and_odd_tails() {
    let mut rng = Rng::new(0x51D_0005);
    for bits in 2u32..=4 {
        let lmax = (1i32 << (bits - 1)) - 1;
        for len in [0usize, 1, 2, 3, 7, 8, 15, 64, 101] {
            let levels: Vec<i8> = (0..len)
                .map(|_| (rng.below((2 * lmax + 1) as usize) as i32 - lmax) as i8)
                .collect();
            let packed = pack_nibbles(&levels);
            assert_eq!(packed.len(), len.div_ceil(2), "bits={bits} len={len}");
            assert_eq!(unpack_nibbles(&packed, len), levels, "bits={bits} len={len}");
            // odd lengths leave the last high nibble zero — a levels
            // buffer extended by one zero packs to the same bytes
            if len % 2 == 1 {
                let mut padded = levels.clone();
                padded.push(0);
                assert_eq!(pack_nibbles(&padded), packed, "bits={bits} len={len} pad");
            }
        }
    }
}
