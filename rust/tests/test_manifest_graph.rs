//! Cross-layer contract tests: the AOT manifests (Layer 2's exported
//! interface) vs the Rust trace graphs / search spaces (Layer 3's view of
//! the same models). A drift between python/compile/models and
//! rust/src/graph/builders fails here.

use geta::graph;
use geta::runtime::Manifest;

fn art() -> Option<std::path::PathBuf> {
    let p = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if p.join("index.json").exists() {
        Some(p)
    } else {
        eprintln!("skipping: run `make artifacts`");
        None
    }
}

#[test]
fn every_group_member_tensor_exists_in_manifest() {
    let Some(dir) = art() else { return };
    for model in Manifest::list_models(&dir).unwrap() {
        let man = Manifest::load(&dir, &model).unwrap();
        let names: std::collections::BTreeSet<&str> =
            man.params.iter().map(|(n, _)| n.as_str()).collect();
        let shapes: std::collections::BTreeMap<&str, &Vec<usize>> =
            man.params.iter().map(|(n, s)| (n.as_str(), s)).collect();
        let space = graph::search_space_for(&man.config).unwrap();
        assert!(!space.groups.is_empty(), "{model}: empty search space");
        for g in &space.groups {
            for m in &g.members {
                assert!(
                    names.contains(m.tensor.as_str()),
                    "{model}: group {} references unknown tensor {}",
                    g.label,
                    m.tensor
                );
                let shape = shapes[m.tensor.as_str()];
                assert!(m.axis < shape.len(), "{model}: {} axis {}", m.tensor, m.axis);
                for &i in &m.indices {
                    assert!(
                        i < shape[m.axis],
                        "{model}: {} idx {i} >= {}",
                        m.tensor,
                        shape[m.axis]
                    );
                }
            }
        }
    }
}

#[test]
fn groups_partition_without_out_overlap() {
    // No element may belong to two groups' OUT members — groups are
    // minimally removable structures, removal must be independent.
    let Some(dir) = art() else { return };
    for model in Manifest::list_models(&dir).unwrap() {
        let man = Manifest::load(&dir, &model).unwrap();
        let space = graph::search_space_for(&man.config).unwrap();
        let mut seen: std::collections::BTreeSet<(String, usize, usize)> =
            std::collections::BTreeSet::new();
        for g in &space.groups {
            for m in g.out_members() {
                for &i in &m.indices {
                    let key = (m.tensor.clone(), m.axis, i);
                    assert!(
                        seen.insert(key),
                        "{model}: duplicate out member {}:{}:{} (group {})",
                        m.tensor,
                        m.axis,
                        i,
                        g.label
                    );
                }
            }
        }
    }
}

#[test]
fn weight_sites_map_to_real_params() {
    let Some(dir) = art() else { return };
    for model in Manifest::list_models(&dir).unwrap() {
        let man = Manifest::load(&dir, &model).unwrap();
        let names: std::collections::BTreeSet<&str> =
            man.params.iter().map(|(n, _)| n.as_str()).collect();
        for s in &man.qsites {
            if let Some(p) = &s.param {
                assert!(names.contains(p.as_str()), "{model}: site {} -> missing {p}", s.name);
            }
        }
    }
}

#[test]
fn layer_costs_cover_params_proportionally() {
    // every weight-carrying 2D/4D tensor should appear in the BOPs model
    let Some(dir) = art() else { return };
    for model in Manifest::list_models(&dir).unwrap() {
        let man = Manifest::load(&dir, &model).unwrap();
        let costs = geta::metrics::layer_costs(&man.config).unwrap();
        let cost_names: std::collections::BTreeSet<&str> =
            costs.iter().map(|c| c.param.as_str()).collect();
        for (name, shape) in &man.params {
            let is_weight = name.ends_with(".weight") && shape.len() >= 2;
            if is_weight {
                assert!(
                    cost_names.contains(name.as_str()),
                    "{model}: no MAC cost for {name}"
                );
            }
        }
    }
}

#[test]
fn attention_models_have_head_groups() {
    let Some(dir) = art() else { return };
    for model in ["bert_mini", "gpt_mini", "vit_mini", "swin_mini"] {
        let man = Manifest::load(&dir, model).unwrap();
        let space = graph::search_space_for(&man.config).unwrap();
        let heads = space
            .groups
            .iter()
            .filter(|g| g.label.contains(":head"))
            .count();
        assert!(heads > 0, "{model}: no head-granular groups");
        let heads_cfg = man.config.usize_or("heads", 0);
        assert_eq!(heads % heads_cfg, 0, "{model}");
    }
}
