//! Cross-layer contract tests: the model manifests (Layer 2's exported
//! interface) vs the Rust trace graphs / search spaces (Layer 3's view of
//! the same models). A drift between python/compile/models and
//! rust/src/graph/builders fails here.
//!
//! Runs on every machine: with `make artifacts` the AOT-exported manifests
//! are checked; without them the natively synthesized manifests (same
//! plan-mirroring contract, see runtime/native.rs) stand in, so the
//! manifest ↔ graph invariants are asserted for all nine models either way.

mod common;

use common::art_dir;
use geta::graph;
use geta::runtime::{available_models, manifest_for, Manifest};

/// All model manifests, from artifacts when present, else synthesized.
/// `available_models` unions the artifact index with the embedded config
/// set, so all nine models are always covered here.
fn manifests() -> Vec<Manifest> {
    let dir = art_dir();
    let models = available_models(&dir);
    assert!(models.len() >= 9, "model set too small: {models:?}");
    models
        .iter()
        .map(|m| manifest_for(&dir, m).unwrap())
        .collect()
}

#[test]
fn every_group_member_tensor_exists_in_manifest() {
    for man in manifests() {
        let model = &man.model;
        let names: std::collections::BTreeSet<&str> =
            man.params.iter().map(|(n, _)| n.as_str()).collect();
        let shapes: std::collections::BTreeMap<&str, &Vec<usize>> =
            man.params.iter().map(|(n, s)| (n.as_str(), s)).collect();
        let space = graph::search_space_for(&man.config).unwrap();
        assert!(!space.groups.is_empty(), "{model}: empty search space");
        for g in &space.groups {
            for m in &g.members {
                assert!(
                    names.contains(m.tensor.as_str()),
                    "{model}: group {} references unknown tensor {}",
                    g.label,
                    m.tensor
                );
                let shape = shapes[m.tensor.as_str()];
                assert!(m.axis < shape.len(), "{model}: {} axis {}", m.tensor, m.axis);
                for &i in &m.indices {
                    assert!(
                        i < shape[m.axis],
                        "{model}: {} idx {i} >= {}",
                        m.tensor,
                        shape[m.axis]
                    );
                }
            }
        }
    }
}

#[test]
fn groups_partition_without_out_overlap() {
    // No element may belong to two groups' OUT members — groups are
    // minimally removable structures, removal must be independent.
    for man in manifests() {
        let model = &man.model;
        let space = graph::search_space_for(&man.config).unwrap();
        let mut seen: std::collections::BTreeSet<(String, usize, usize)> =
            std::collections::BTreeSet::new();
        for g in &space.groups {
            for m in g.out_members() {
                for &i in &m.indices {
                    let key = (m.tensor.clone(), m.axis, i);
                    assert!(
                        seen.insert(key),
                        "{model}: duplicate out member {}:{}:{} (group {})",
                        m.tensor,
                        m.axis,
                        i,
                        g.label
                    );
                }
            }
        }
    }
}

#[test]
fn weight_sites_map_to_real_params() {
    for man in manifests() {
        let model = &man.model;
        let names: std::collections::BTreeSet<&str> =
            man.params.iter().map(|(n, _)| n.as_str()).collect();
        for s in &man.qsites {
            if let Some(p) = &s.param {
                assert!(names.contains(p.as_str()), "{model}: site {} -> missing {p}", s.name);
            }
        }
    }
}

#[test]
fn layer_costs_cover_params_proportionally() {
    // every weight-carrying 2D/4D tensor should appear in the BOPs model
    for man in manifests() {
        let model = &man.model;
        let costs = geta::metrics::layer_costs(&man.config).unwrap();
        let cost_names: std::collections::BTreeSet<&str> =
            costs.iter().map(|c| c.param.as_str()).collect();
        for (name, shape) in &man.params {
            let is_weight = name.ends_with(".weight") && shape.len() >= 2;
            if is_weight {
                assert!(
                    cost_names.contains(name.as_str()),
                    "{model}: no MAC cost for {name}"
                );
            }
        }
    }
}

#[test]
fn attention_models_have_head_groups() {
    let dir = art_dir();
    for model in ["bert_mini", "gpt_mini", "vit_mini", "swin_mini"] {
        let man = manifest_for(&dir, model).unwrap();
        let space = graph::search_space_for(&man.config).unwrap();
        let heads = space
            .groups
            .iter()
            .filter(|g| g.label.contains(":head"))
            .count();
        assert!(heads > 0, "{model}: no head-granular groups");
        let heads_cfg = man.config.usize_or("heads", 0);
        assert_eq!(heads % heads_cfg, 0, "{model}");
    }
}

#[test]
fn native_and_aot_manifests_agree_when_both_exist() {
    // when artifacts are present, the synthesized manifest must match the
    // AOT-exported one tensor-for-tensor — the contract that makes the
    // native fallback a faithful stand-in
    let dir = art_dir();
    if !dir.join("index.json").exists() {
        eprintln!("skipping: no artifacts to compare against");
        return;
    }
    for model in available_models(&dir) {
        if !geta::runtime::has_artifact(&dir, &model) {
            continue; // natively described only — nothing to compare
        }
        let aot = Manifest::load(&dir, &model).unwrap();
        let Ok(native) = geta::runtime::native::synth_manifest_for(&model) else {
            continue; // model unknown to the embedded config set
        };
        assert_eq!(aot.params, native.params, "{model}: param plan drift");
        assert_eq!(aot.qsites.len(), native.qsites.len(), "{model}");
        for (a, b) in aot.qsites.iter().zip(&native.qsites) {
            assert_eq!(a.name, b.name, "{model}");
            assert_eq!(a.param, b.param, "{model}");
        }
        assert_eq!(aot.batch.x_shape, native.batch.x_shape, "{model}");
        assert_eq!(aot.batch.y_shape, native.batch.y_shape, "{model}");
        assert_eq!(aot.param_count, native.param_count, "{model}");
    }
}
