//! Telemetry acceptance tests.
//!
//! The contract the obs subsystem ships under:
//!   1. **Bitwise identity** — enabling the span tracer must not change a
//!      single logit bit: all timing wraps the numeric kernels from the
//!      outside.
//!   2. **Coverage** — a traced inference pass emits one exec span per
//!      executed plan node, named by op kind (and kernel kind for the GEMM
//!      ops), and the drained events serialize to Chrome trace-event JSON
//!      that our own parser accepts.
//!   3. **Registry** — the global metrics registry exposes everything it
//!      holds in Prometheus text form and as a parseable JSON snapshot.
//!
//! Tracing state is process-global, so the traced/untraced comparison and
//! the span-coverage checks run inside ONE test function instead of racing
//! across the harness's worker threads.

mod common;

use common::art_dir;
use geta::util::json::Json;

#[test]
fn traced_inference_is_bitwise_identical_and_covers_the_plan() {
    let art = geta::report::train_export(&art_dir(), "mlp_tiny", 0.05, 0.5, 8.0).unwrap();
    let engine = geta::deploy::GetaEngine::from_container_kernel(
        &art.container,
        geta::deploy::KernelKind::Int8,
    )
    .unwrap();
    let (_, eval) = geta::data::SynthData::for_model(engine.config(), 1, 32, 1);
    let idxs: Vec<usize> = (0..eval.len()).collect();
    let (x, _y) = eval.batch(&idxs);

    // untraced baseline; drop anything previously buffered
    let prev = geta::obs::set_enabled(false);
    let base = engine.infer(&x).unwrap();
    let _ = geta::obs::trace::drain();

    // traced run over identical input
    geta::obs::set_enabled(true);
    let traced = engine.infer(&x).unwrap();
    geta::obs::set_enabled(prev);
    let events = geta::obs::trace::drain();

    assert_eq!(base.len(), traced.len());
    for (i, (a, b)) in base.iter().zip(&traced).enumerate() {
        assert_eq!(
            a.to_bits(),
            b.to_bits(),
            "logit {i} differs traced vs untraced: {a} vs {b}"
        );
    }

    // per-node exec spans, keyed op/kernel for the GEMM ops
    let exec: Vec<_> = events.iter().filter(|e| e.cat == "exec").collect();
    assert!(!exec.is_empty(), "traced inference recorded no exec spans");
    assert!(
        exec.iter()
            .any(|e| e.name.starts_with("Linear/int8") || e.name.starts_with("Linear/f32")),
        "no kernel-keyed Linear span; names: {:?}",
        exec.iter().map(|e| e.name.as_str()).collect::<Vec<_>>()
    );
    for e in &exec {
        assert!(e.ts_us >= 0.0 && e.dur_us >= 0.0, "bad span bounds: {e:?}");
    }

    // the aggregate the profile table prints: every span name accounted
    let agg = geta::obs::trace::aggregate(&events, Some("exec"));
    let agg_calls: u64 = agg.iter().map(|r| r.calls).sum();
    assert_eq!(agg_calls, exec.len() as u64);
    for w in agg.windows(2) {
        assert!(w[0].total_us >= w[1].total_us, "aggregate not sorted by total");
    }

    // drained events round-trip through the Chrome trace-event writer
    let text = geta::obs::trace::chrome_trace_json(&events).to_string();
    let parsed = geta::util::json::parse(&text).expect("trace JSON parses");
    let Json::Obj(m) = parsed else {
        panic!("trace root is not an object")
    };
    let Some(Json::Arr(rows)) = m.get("traceEvents") else {
        panic!("traceEvents missing or not an array")
    };
    assert_eq!(rows.len(), events.len());
}

#[test]
fn global_registry_exposes_and_snapshots() {
    let reg = geta::obs::metrics::global();
    reg.counter("test_obs_demo_total").add(3);
    reg.gauge("test_obs_demo_depth").set(-2);
    reg.histogram("test_obs_demo_us").record_us(150.0);

    let text = reg.exposition();
    assert!(text.contains("# TYPE test_obs_demo_total counter"));
    assert!(text.contains("# TYPE test_obs_demo_depth gauge"));
    assert!(text.contains("# TYPE test_obs_demo_us summary"));
    assert!(text.contains("test_obs_demo_us_count 1"));
    for line in text.lines().filter(|l| !l.starts_with('#')) {
        let mut parts = line.rsplitn(2, ' ');
        let val = parts.next().unwrap();
        assert!(val.parse::<f64>().is_ok(), "unparseable sample line: {line}");
    }

    let path = std::env::temp_dir().join("geta_test_obs_snapshot.json");
    reg.write_snapshot(&path).unwrap();
    let doc = geta::util::json::parse_file(&path).unwrap();
    std::fs::remove_file(&path).ok();
    let Json::Obj(m) = doc else {
        panic!("snapshot root is not an object")
    };
    for key in ["counters", "gauges", "histograms"] {
        assert!(matches!(m.get(key), Some(Json::Obj(_))), "missing {key}");
    }
}
