//! Golden-vector validation: the Rust quantizer (rust/src/quant) and the
//! native interpreter's structural ops (rust/src/tensor/ops.rs) against
//! the numpy oracle's exported vectors.
//!
//! Quantizer vectors come from the full `artifacts/quant_vectors.json`
//! (written by `python -m compile.vectors` during `make artifacts`) or the
//! checked-in `rust/tests/data/quant_vectors_small.json`; the interpreter
//! op vectors (conv2d forward/backward on the im2col path, layernorm,
//! softmax) are always the checked-in
//! `rust/tests/data/op_vectors_small.json`. Both small sets are generated
//! by scripts/gen_quant_vectors.py, so this suite asserts on every machine
//! with zero Python installed.

use geta::quant::{self, QParams};
use geta::tensor::{
    col2im, conv_out_dim, gelu, gelu_grad, im2col, layernorm_bwd_rows, layernorm_rows, matmul,
    matmul_f32u4_scaled_into, matmul_i8u4_scaled_into, matmul_nt, matmul_tn, matmul_u4,
    softmax_bwd_rows, softmax_rows, U4Weight,
};
use geta::util::json;

fn vectors() -> json::Json {
    let full = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/quant_vectors.json");
    let path = if full.exists() {
        full
    } else {
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("rust/tests/data/quant_vectors_small.json")
    };
    json::parse_file(&path).unwrap()
}

fn check_case(case: &json::Json) {
    let d = case.f64_or("d", 0.0) as f32;
    let t = case.f64_or("t", 0.0) as f32;
    let qm = case.f64_or("qm", 0.0) as f32;
    let q = QParams { d, t, qm };
    let x = case.get("x").unwrap().f32_arr();
    let want_xq = case.get("xq").unwrap().f32_arr();
    let want_clip = case.get("clip").unwrap().f32_arr();
    let want_res = case.get("residual").unwrap().f32_arr();
    let want_gd = case.get("grad_d").unwrap().f32_arr();
    let want_gt = case.get("grad_t").unwrap().f32_arr();
    let want_gqm = case.get("grad_qm").unwrap().f32_arr();
    // exp/pow orderings differ between jnp and rust: a 1-ulp c difference
    // scaled by 1/d can flip a round — accept fp-grade tolerances plus
    // round-flip (+-1) deltas on residual-derived quantities.
    let tol = 1e-4 * (1.0 + qm.powf(t) / d * f32::EPSILON * 64.0);
    for i in 0..x.len() {
        let xi = x[i];
        assert!(
            (quant::fake_quant(xi, &q) - want_xq[i]).abs() <= tol.max(d * 1.0 + 1e-5),
            "xq[{i}]: {} vs {} (d={d},t={t},qm={qm},x={xi})",
            quant::fake_quant(xi, &q),
            want_xq[i]
        );
        assert!(
            (quant::clip_pow(xi, &q) - want_clip[i]).abs() <= 1e-4,
            "clip[{i}]"
        );
        let dres = quant::residual(xi, &q) - want_res[i];
        assert!(
            (dres - dres.round()).abs() <= 1e-3,
            "residual[{i}]: {} vs {}",
            quant::residual(xi, &q),
            want_res[i]
        );
        let dgd = quant::grad_d(xi, &q) - want_gd[i];
        assert!((dgd - dgd.round()).abs() <= 1e-3, "grad_d[{i}]");
        assert!(
            (quant::grad_t(xi, &q) - want_gt[i]).abs() <= 1e-3 * (1.0 + want_gt[i].abs()),
            "grad_t[{i}]: {} vs {}",
            quant::grad_t(xi, &q),
            want_gt[i]
        );
        assert!(
            (quant::grad_qm(xi, &q) - want_gqm[i]).abs() <= 1e-4,
            "grad_qm[{i}]"
        );
    }
    let want_b = case.f64_or("bit_width", 0.0) as f32;
    assert!(
        (q.bit_width() - want_b).abs() < 1e-3,
        "bit width {} vs {want_b}",
        q.bit_width()
    );
}

#[test]
fn rust_quant_matches_oracle_vectors() {
    let v = vectors();
    let cases = v.get("cases").unwrap().as_arr().unwrap();
    assert!(cases.len() >= 5);
    for case in cases {
        check_case(case);
    }
}

// ------------------------------------------------- interpreter op vectors

const OP_TOL: f32 = 1e-5;

fn op_vectors() -> json::Json {
    let path =
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("rust/tests/data/op_vectors_small.json");
    json::parse_file(&path).unwrap()
}

fn assert_close(got: &[f32], want: &[f32], what: &str) {
    assert_eq!(got.len(), want.len(), "{what}: length");
    for i in 0..got.len() {
        assert!(
            (got[i] - want[i]).abs() <= OP_TOL * (1.0 + want[i].abs()),
            "{what}[{i}]: {} vs {}",
            got[i],
            want[i]
        );
    }
}

fn check_conv_case(case: &json::Json) {
    let (b, h, w) = (
        case.usize_or("b", 0),
        case.usize_or("h", 0),
        case.usize_or("w", 0),
    );
    let (cin, cout, k) = (
        case.usize_or("cin", 0),
        case.usize_or("cout", 0),
        case.usize_or("k", 0),
    );
    let stride = case.usize_or("stride", 1);
    let same = case.bool_or("same", true);
    let x = case.get("x").unwrap().f32_arr();
    let wt = case.get("weight").unwrap().f32_arr();
    let bias = case.get("bias").unwrap().f32_arr();
    let (ho, pad) = conv_out_dim(h, k, stride, same);
    let (wo, _) = conv_out_dim(w, k, stride, same);
    // forward: im2col + GEMM + bias
    let cols = im2col(&x, b, h, w, cin, k, stride, pad, ho, wo);
    let rows = b * ho * wo;
    let mut y = matmul(&cols, &wt, rows, k * k * cin, cout);
    for r in 0..rows {
        for j in 0..cout {
            y[r * cout + j] += bias[j];
        }
    }
    assert_close(&y, &case.get("y").unwrap().f32_arr(), "conv y");
    // backward
    let cot = case.get("cot").unwrap().f32_arr();
    let gw = matmul_tn(&cols, &cot, rows, k * k * cin, cout);
    assert_close(&gw, &case.get("gw").unwrap().f32_arr(), "conv gw");
    let mut gb = vec![0.0f32; cout];
    for r in 0..rows {
        for j in 0..cout {
            gb[j] += cot[r * cout + j];
        }
    }
    assert_close(&gb, &case.get("gb").unwrap().f32_arr(), "conv gb");
    let gcols = matmul_nt(&cot, &wt, rows, cout, k * k * cin);
    let gx = col2im(&gcols, b, h, w, cin, k, stride, pad, ho, wo);
    assert_close(&gx, &case.get("gx").unwrap().f32_arr(), "conv gx");
}

fn check_layernorm_case(case: &json::Json) {
    let (rows, c) = (case.usize_or("rows", 0), case.usize_or("c", 0));
    let x = case.get("x").unwrap().f32_arr();
    let gamma = case.get("gamma").unwrap().f32_arr();
    let beta = case.get("beta").unwrap().f32_arr();
    let (y, aux) = layernorm_rows(&x, &gamma, &beta, rows, c, 1e-5);
    assert_close(&y, &case.get("y").unwrap().f32_arr(), "ln y");
    let cot = case.get("cot").unwrap().f32_arr();
    let (gx, ggamma, gbeta) = layernorm_bwd_rows(&gamma, &cot, &aux, rows, c);
    assert_close(&gx, &case.get("gx").unwrap().f32_arr(), "ln gx");
    assert_close(&ggamma, &case.get("ggamma").unwrap().f32_arr(), "ln ggamma");
    assert_close(&gbeta, &case.get("gbeta").unwrap().f32_arr(), "ln gbeta");
}

fn check_softmax_case(case: &json::Json) {
    let (rows, n) = (case.usize_or("rows", 0), case.usize_or("n", 0));
    let mut p = case.get("x").unwrap().f32_arr();
    softmax_rows(&mut p, rows, n);
    assert_close(&p, &case.get("p").unwrap().f32_arr(), "softmax p");
    let cot = case.get("cot").unwrap().f32_arr();
    let gx = softmax_bwd_rows(&p, &cot, rows, n);
    assert_close(&gx, &case.get("gx").unwrap().f32_arr(), "softmax gx");
}

/// Multi-head attention (QK^T / softmax / V) forward + (dq, dk, dv)
/// backward, replayed through the same tensor-op sequence the interpreter
/// (runtime/interp.rs OpKind::Attention) executes per head.
fn check_attention_case(case: &json::Json) {
    let (b, s, d, heads) = (
        case.usize_or("b", 0),
        case.usize_or("s", 0),
        case.usize_or("d", 0),
        case.usize_or("heads", 1),
    );
    let causal = case.bool_or("causal", false);
    let hd = d / heads;
    let scale = 1.0 / (hd as f32).sqrt();
    let qv = case.get("q").unwrap().f32_arr();
    let kv = case.get("k").unwrap().f32_arr();
    let vv = case.get("v").unwrap().f32_arr();
    let cot = case.get("cot").unwrap().f32_arr();
    let mut y = vec![0.0f32; b * s * d];
    let mut gq = vec![0.0f32; b * s * d];
    let mut gk = vec![0.0f32; b * s * d];
    let mut gv = vec![0.0f32; b * s * d];
    let mut qh = vec![0.0f32; s * hd];
    let mut kh = vec![0.0f32; s * hd];
    let mut vh = vec![0.0f32; s * hd];
    let mut dyh = vec![0.0f32; s * hd];
    for bi in 0..b {
        for head in 0..heads {
            let off = head * hd;
            for t in 0..s {
                let src = (bi * s + t) * d + off;
                qh[t * hd..(t + 1) * hd].copy_from_slice(&qv[src..src + hd]);
                kh[t * hd..(t + 1) * hd].copy_from_slice(&kv[src..src + hd]);
                vh[t * hd..(t + 1) * hd].copy_from_slice(&vv[src..src + hd]);
                dyh[t * hd..(t + 1) * hd].copy_from_slice(&cot[src..src + hd]);
            }
            let mut att = matmul_nt(&qh, &kh, s, hd, s);
            for v in att.iter_mut() {
                *v *= scale;
            }
            if causal {
                for i in 0..s {
                    for j in i + 1..s {
                        att[i * s + j] = -1e9;
                    }
                }
            }
            softmax_rows(&mut att, s, s);
            let yh = matmul(&att, &vh, s, s, hd);
            // backward: dP = dY V^T, dV = P^T dY, dS = softmax'(P, dP)·scale
            let dp = matmul_nt(&dyh, &vh, s, hd, s);
            let dvh = matmul_tn(&att, &dyh, s, s, hd);
            let mut ds = softmax_bwd_rows(&att, &dp, s, s);
            for v in ds.iter_mut() {
                *v *= scale;
            }
            let dqh = matmul(&ds, &kh, s, s, hd);
            let dkh = matmul_tn(&ds, &qh, s, s, hd);
            for t in 0..s {
                let dst = (bi * s + t) * d + off;
                y[dst..dst + hd].copy_from_slice(&yh[t * hd..(t + 1) * hd]);
                gq[dst..dst + hd].copy_from_slice(&dqh[t * hd..(t + 1) * hd]);
                gk[dst..dst + hd].copy_from_slice(&dkh[t * hd..(t + 1) * hd]);
                gv[dst..dst + hd].copy_from_slice(&dvh[t * hd..(t + 1) * hd]);
            }
        }
    }
    assert_close(&y, &case.get("y").unwrap().f32_arr(), "attention y");
    assert_close(&gq, &case.get("gq").unwrap().f32_arr(), "attention gq");
    assert_close(&gk, &case.get("gk").unwrap().f32_arr(), "attention gk");
    assert_close(&gv, &case.get("gv").unwrap().f32_arr(), "attention gv");
}

fn check_gelu_case(case: &json::Json) {
    let x = case.get("x").unwrap().f32_arr();
    let cot = case.get("cot").unwrap().f32_arr();
    let y: Vec<f32> = x.iter().map(|&v| gelu(v)).collect();
    assert_close(&y, &case.get("y").unwrap().f32_arr(), "gelu y");
    let gx: Vec<f32> = x.iter().zip(&cot).map(|(&v, &c)| c * gelu_grad(v)).collect();
    assert_close(&gx, &case.get("gx").unwrap().f32_arr(), "gelu gx");
}

#[test]
fn native_ops_match_numpy_golden_vectors() {
    let v = op_vectors();
    let cases = v.get("cases").unwrap().as_arr().unwrap();
    let mut seen = std::collections::BTreeMap::new();
    for case in cases {
        let kind = case.str_or("kind", "");
        *seen.entry(kind.clone()).or_insert(0usize) += 1;
        match kind.as_str() {
            "conv2d" => check_conv_case(case),
            "layernorm" => check_layernorm_case(case),
            "softmax" => check_softmax_case(case),
            "attention" => check_attention_case(case),
            "gelu" => check_gelu_case(case),
            other => panic!("unknown op vector kind {other}"),
        }
    }
    // every interpreter op the conv/attention families depend on must be
    // covered: conv in several padding/stride regimes, attention in both
    // bidirectional and causal form, plus the norm/softmax/gelu kernels
    assert!(seen["conv2d"] >= 4, "{seen:?}");
    assert!(seen["layernorm"] >= 2, "{seen:?}");
    assert!(seen["softmax"] >= 2, "{seen:?}");
    assert!(seen["attention"] >= 2, "{seen:?}");
    assert!(seen["gelu"] >= 2, "{seen:?}");
}

// --------------------------------------------------- u4 GEMM golden vectors

fn i64_arr(case: &json::Json, key: &str) -> Vec<i64> {
    case.get(key)
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|v| v.as_i64().unwrap())
        .collect()
}

/// The numpy oracle packs nibbles independently (scripts/gen_quant_vectors.py
/// `pack_nibble_rows`); matching its bytes byte-for-byte pins the panel
/// layout — LSB-first, low nibble = even column, `[k, ceil(n/2)]` row-major
/// — across the two languages, not just within Rust. Raw i32 outputs are
/// exact (both sides accumulate integer); both scaled epilogues follow the
/// same f64 discipline, so 1e-5 holds with plenty of headroom.
#[test]
fn u4_kernels_match_numpy_golden_vectors_and_packed_layout() {
    let path =
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("rust/tests/data/u4_vectors_small.json");
    let v = json::parse_file(&path).unwrap();
    let cases = v.get("cases").unwrap().as_arr().unwrap();
    assert!(cases.len() >= 5);
    for case in cases {
        let (m, k, n) = (
            case.usize_or("m", 0),
            case.usize_or("k", 0),
            case.usize_or("n", 0),
        );
        let levels: Vec<i32> = i64_arr(case, "levels").iter().map(|&v| v as i32).collect();
        let packed: Vec<u8> = i64_arr(case, "packed").iter().map(|&v| v as u8).collect();
        let mut w = U4Weight::from_levels(&levels, n, 0.0).expect("levels fit 4 bits");
        assert_eq!((w.k, w.n), (k, n), "m={m} k={k} n={n}");
        assert_eq!(
            w.packed, packed,
            "nibble layout drifted from the numpy packer at k={k} n={n}"
        );
        w.scale = case.get("scale").unwrap().f32_arr();
        let bias = case.get("bias").unwrap().f32_arr();
        let la: Vec<i8> = i64_arr(case, "acts_i8").iter().map(|&v| v as i8).collect();
        // raw integer GEMM: exact equality, no tolerance
        let raw_want: Vec<i32> = i64_arr(case, "raw").iter().map(|&v| v as i32).collect();
        assert_eq!(matmul_u4(&la, &w, m), raw_want, "raw u4 GEMM at k={k} n={n}");
        // i8 x u4 with the f64 scale epilogue
        let alpha = case.f64_or("alpha", 0.0) as f32;
        let mut got = vec![0.0f32; m * n];
        matmul_i8u4_scaled_into(&mut got, &la, &w, m, alpha, Some(&bias));
        assert_close(&got, &case.get("scaled").unwrap().f32_arr(), "u4 scaled");
        // mixed f32 x u4 (weight-only quantization)
        let af = case.get("acts_f32").unwrap().f32_arr();
        matmul_f32u4_scaled_into(&mut got, &af, &w, m, Some(&bias));
        assert_close(&got, &case.get("mixed").unwrap().f32_arr(), "u4 mixed");
    }
}
