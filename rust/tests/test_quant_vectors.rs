//! Golden-vector validation: the Rust quantizer (rust/src/quant) against
//! the oracle's exported vectors.
//!
//! Two vector sets exist: the full `artifacts/quant_vectors.json` written
//! by `python -m compile.vectors` during `make artifacts`, and the
//! checked-in `rust/tests/data/quant_vectors_small.json` generated once
//! from the same float32 oracle math (scripts/gen_quant_vectors.py), so
//! this suite asserts on every machine with zero Python installed.

use geta::quant::{self, QParams};
use geta::util::json;

fn vectors() -> json::Json {
    let full = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/quant_vectors.json");
    let path = if full.exists() {
        full
    } else {
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("rust/tests/data/quant_vectors_small.json")
    };
    json::parse_file(&path).unwrap()
}

fn check_case(case: &json::Json) {
    let d = case.f64_or("d", 0.0) as f32;
    let t = case.f64_or("t", 0.0) as f32;
    let qm = case.f64_or("qm", 0.0) as f32;
    let q = QParams { d, t, qm };
    let x = case.get("x").unwrap().f32_arr();
    let want_xq = case.get("xq").unwrap().f32_arr();
    let want_clip = case.get("clip").unwrap().f32_arr();
    let want_res = case.get("residual").unwrap().f32_arr();
    let want_gd = case.get("grad_d").unwrap().f32_arr();
    let want_gt = case.get("grad_t").unwrap().f32_arr();
    let want_gqm = case.get("grad_qm").unwrap().f32_arr();
    // exp/pow orderings differ between jnp and rust: a 1-ulp c difference
    // scaled by 1/d can flip a round — accept fp-grade tolerances plus
    // round-flip (+-1) deltas on residual-derived quantities.
    let tol = 1e-4 * (1.0 + qm.powf(t) / d * f32::EPSILON * 64.0);
    for i in 0..x.len() {
        let xi = x[i];
        assert!(
            (quant::fake_quant(xi, &q) - want_xq[i]).abs() <= tol.max(d * 1.0 + 1e-5),
            "xq[{i}]: {} vs {} (d={d},t={t},qm={qm},x={xi})",
            quant::fake_quant(xi, &q),
            want_xq[i]
        );
        assert!(
            (quant::clip_pow(xi, &q) - want_clip[i]).abs() <= 1e-4,
            "clip[{i}]"
        );
        let dres = quant::residual(xi, &q) - want_res[i];
        assert!(
            (dres - dres.round()).abs() <= 1e-3,
            "residual[{i}]: {} vs {}",
            quant::residual(xi, &q),
            want_res[i]
        );
        let dgd = quant::grad_d(xi, &q) - want_gd[i];
        assert!((dgd - dgd.round()).abs() <= 1e-3, "grad_d[{i}]");
        assert!(
            (quant::grad_t(xi, &q) - want_gt[i]).abs() <= 1e-3 * (1.0 + want_gt[i].abs()),
            "grad_t[{i}]: {} vs {}",
            quant::grad_t(xi, &q),
            want_gt[i]
        );
        assert!(
            (quant::grad_qm(xi, &q) - want_gqm[i]).abs() <= 1e-4,
            "grad_qm[{i}]"
        );
    }
    let want_b = case.f64_or("bit_width", 0.0) as f32;
    assert!(
        (q.bit_width() - want_b).abs() < 1e-3,
        "bit width {} vs {want_b}",
        q.bit_width()
    );
}

#[test]
fn rust_quant_matches_oracle_vectors() {
    let v = vectors();
    let cases = v.get("cases").unwrap().as_arr().unwrap();
    assert!(cases.len() >= 5);
    for case in cases {
        check_case(case);
    }
}
