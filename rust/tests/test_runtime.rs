//! Integration tests over the execution backends. Every zoo family runs on
//! every machine: the native interpreter lowers conv *and* attention
//! models, so none of these tests may skip (see `common::skip_or_panic` —
//! a lowered family failing to produce a backend panics).

mod common;

use common::art_dir;
use geta::config::ExperimentConfig;
use geta::coordinator::Trainer;
use geta::quant::QParams;
use geta::runtime::{load_backend, Backend};

/// All nine embedded zoo models.
const ZOO: [&str; 9] = [
    "mlp_tiny",
    "vgg7_mini",
    "resnet_mini",
    "resnet_mini_l",
    "bert_mini",
    "gpt_mini",
    "vit_mini",
    "simplevit_mini",
    "swin_mini",
];

/// Backends exist for the whole zoo; failure is always a bug now.
fn backend(model: &str) -> Box<dyn Backend> {
    match load_backend(&art_dir(), model) {
        Ok(b) => b,
        Err(err) => {
            common::skip_or_panic(model, &err);
            panic!("{model} has a native lowering; skip_or_panic must not return");
        }
    }
}

#[test]
fn engine_roundtrip_mlp() {
    let e = backend("mlp_tiny");
    // "cpu" under PJRT, "native" for the reference backend
    assert!(["cpu", "native"].contains(&e.platform().as_str()), "{}", e.platform());
    let params = e.init_params(0);
    assert_eq!(params.len(), e.manifest().params.len());
    // deterministic init
    let params2 = e.init_params(0);
    assert_eq!(params.tensors[0].data, params2.tensors[0].data);
    let q = e.init_qparams(&params, 16.0);
    assert_eq!(q.len(), e.manifest().qsites.len());
    for s in &q {
        assert!((s.bit_width() - 16.0).abs() < 1e-2);
    }

    let exp = ExperimentConfig::defaults_for("mlp_tiny");
    let t = Trainer::new(&art_dir(), exp).unwrap();
    let idxs: Vec<usize> = (0..t.batch_size()).collect();
    let (x, y) = t.train_data.batch(&idxs);
    let out = t.engine.train_step(&params, &q, &x, &y).unwrap();
    assert!(out.loss.is_finite() && out.loss > 0.0);
    assert_eq!(out.grads.len(), params.len());
    for (g, p) in out.grads.tensors.iter().zip(&params.tensors) {
        assert_eq!(g.shape, p.shape, "{}", g.name);
        assert!(g.data.iter().all(|v| v.is_finite()), "{}", g.name);
    }
    assert_eq!(out.qgrads.len(), q.len());
    // eval
    let ev = t.engine.eval_step(&params, &q, &x, &y).unwrap();
    assert!(ev.loss.is_finite());
    assert!(ev.metric >= 0.0 && ev.metric <= t.batch_size() as f32);
}

#[test]
fn engine_roundtrip_every_family() {
    // one full train step + eval step per zoo model: shapes, finiteness,
    // nonzero gradient signal. This is the per-family "no skip" contract.
    for model in ZOO {
        let e = backend(model);
        let params = e.init_params(0);
        assert_eq!(params.len(), e.manifest().params.len(), "{model}");
        let q = e.init_qparams(&params, 8.0);
        let exp = ExperimentConfig::defaults_for(model);
        let t = Trainer::new(&art_dir(), exp).unwrap();
        let idxs: Vec<usize> = (0..t.batch_size()).collect();
        let (x, y) = t.train_data.batch(&idxs);
        let out = e.train_step(&params, &q, &x, &y).unwrap();
        assert!(out.loss.is_finite() && out.loss > 0.0, "{model}: loss {}", out.loss);
        assert_eq!(out.grads.len(), params.len(), "{model}");
        let mut grad_norm = 0.0f64;
        for (g, p) in out.grads.tensors.iter().zip(&params.tensors) {
            assert_eq!(g.shape, p.shape, "{model}: {}", g.name);
            assert!(g.data.iter().all(|v| v.is_finite()), "{model}: {}", g.name);
            grad_norm += geta::tensor::dot(&g.data, &g.data);
        }
        assert!(grad_norm > 0.0, "{model}: all gradients zero");
        assert_eq!(out.qgrads.len(), e.manifest().qsites.len(), "{model}");
        let ev = e.eval_step(&params, &q, &x, &y).unwrap();
        assert!(ev.loss.is_finite(), "{model}");
        assert_eq!(ev.extra.len(), e.manifest().eval_outputs.len() - 2, "{model}");
    }
}

#[test]
fn gradients_flow_to_quant_params() {
    let e = backend("mlp_tiny");
    let params = e.init_params(1);
    // coarse quantizer => large rounding residuals => nonzero d-gradient
    let q = e.init_qparams(&params, 4.0);
    let exp = ExperimentConfig::defaults_for("mlp_tiny");
    let t = Trainer::new(&art_dir(), exp).unwrap();
    let idxs: Vec<usize> = (0..t.batch_size()).collect();
    let (x, y) = t.train_data.batch(&idxs);
    let out = e.train_step(&params, &q, &x, &y).unwrap();
    let any_live = out
        .qgrads
        .iter()
        .any(|g| g.0.abs() + g.1.abs() + g.2.abs() > 0.0);
    assert!(any_live, "quant-param gradients are all zero: {:?}", out.qgrads);
}

#[test]
fn quantizer_bits_change_the_loss() {
    // 2-bit weights must behave differently from 16-bit weights — proves
    // the fake-quant path actually runs inside the backend. Now asserted
    // for a conv family and an attention family too.
    for model in ["mlp_tiny", "resnet_mini", "bert_mini"] {
        let e = backend(model);
        let params = e.init_params(2);
        let exp = ExperimentConfig::defaults_for(model);
        let t = Trainer::new(&art_dir(), exp).unwrap();
        let idxs: Vec<usize> = (0..t.batch_size()).collect();
        let (x, y) = t.train_data.batch(&idxs);
        let hi = e.init_qparams(&params, 16.0);
        let lo = e.init_qparams(&params, 2.0);
        let l_hi = e.eval_step(&params, &hi, &x, &y).unwrap().loss;
        let l_lo = e.eval_step(&params, &lo, &x, &y).unwrap().loss;
        assert!(
            (l_hi - l_lo).abs() > 1e-6,
            "{model}: bit width has no effect: {l_hi} vs {l_lo}"
        );
    }
}

#[test]
fn eval_is_deterministic() {
    for model in ["mlp_tiny", "vit_mini"] {
        let e = backend(model);
        let params = e.init_params(3);
        let q = e.init_qparams(&params, 8.0);
        let exp = ExperimentConfig::defaults_for(model);
        let t = Trainer::new(&art_dir(), exp).unwrap();
        let idxs: Vec<usize> = (0..t.batch_size()).collect();
        let (x, y) = t.eval_data.batch(&idxs);
        let a = e.eval_step(&params, &q, &x, &y).unwrap();
        let b = e.eval_step(&params, &q, &x, &y).unwrap();
        assert_eq!(a.loss, b.loss, "{model}");
        assert_eq!(a.metric, b.metric, "{model}");
    }
}

#[test]
fn span_eval_returns_predictions() {
    // bert has a native lowering now: this test may never skip
    let e = backend("bert_mini");
    let params = e.init_params(0);
    let q = e.init_qparams(&params, 8.0);
    let exp = ExperimentConfig::defaults_for("bert_mini");
    let t = Trainer::new(&art_dir(), exp).unwrap();
    let idxs: Vec<usize> = (0..t.batch_size()).collect();
    let (x, y) = t.eval_data.batch(&idxs);
    let ev = e.eval_step(&params, &q, &x, &y).unwrap();
    assert_eq!(ev.extra.len(), 2); // pred_start, pred_end
    assert_eq!(ev.extra[0].len(), t.batch_size());
    let seq = e.manifest().config.usize_or("seq_len", 32) as f32;
    assert!(ev.extra[0].iter().all(|&p| p >= 0.0 && p < seq));
}

#[test]
fn lm_eval_reports_mask_count() {
    let e = backend("gpt_mini");
    let params = e.init_params(0);
    let q = e.init_qparams(&params, 8.0);
    let exp = ExperimentConfig::defaults_for("gpt_mini");
    let t = Trainer::new(&art_dir(), exp).unwrap();
    let idxs: Vec<usize> = (0..t.batch_size()).collect();
    let (x, y) = t.eval_data.batch(&idxs);
    let ev = e.eval_step(&params, &q, &x, &y).unwrap();
    assert_eq!(ev.extra.len(), 1);
    let seq = e.manifest().config.usize_or("seq_len", 32);
    // one masked position per sequence (the final token)
    assert_eq!(ev.extra[0][0], (t.batch_size() * (seq - 1)) as f32);
}

#[test]
fn degenerate_qparams_do_not_crash() {
    // pathological quantizers must yield finite losses, not NaNs
    let e = backend("mlp_tiny");
    let params = e.init_params(4);
    let exp = ExperimentConfig::defaults_for("mlp_tiny");
    let t = Trainer::new(&art_dir(), exp).unwrap();
    let idxs: Vec<usize> = (0..t.batch_size()).collect();
    let (x, y) = t.train_data.batch(&idxs);
    for q in [
        QParams { d: 1e-8, t: 1.0, qm: 1.0 },
        QParams { d: 10.0, t: 1.0, qm: 1e-3 },
        QParams { d: 0.1, t: 2.0, qm: 4.0 },
    ] {
        let qs = vec![q; e.manifest().qsites.len()];
        let out = e.eval_step(&params, &qs, &x, &y).unwrap();
        assert!(out.loss.is_finite(), "{q:?}");
    }
}
