//! Serving-layer obligations (`geta::serve` + the engine's concurrent
//! `infer` path):
//!
//! 1. **Determinism under coalescing** — logits served through the
//!    batching server are bitwise identical to direct per-request
//!    `engine.infer` calls at every (workers, batch-window, max-batch)
//!    combination: coalescing preserves each request's micro-batch chunk
//!    boundaries, so batch-statistics normalization never shifts.
//! 2. **Concurrent inference** — threads calling `infer` on one shared
//!    engine get bit-identical results to sequential calls (the arena
//!    pool replaced the old serializing `Mutex<Arena>`).
//! 3. **Backpressure** — a saturated bounded queue sheds with the typed
//!    `ServeError::QueueFull`, never blocks or panics, and the server
//!    keeps serving afterwards.
//! 4. **Drain-on-shutdown** — every accepted request completes before
//!    `shutdown` returns; none are lost.
//! 5. **Load-once cache** — two lookups of one artifact share a single
//!    engine.
//!
//! One short mlp_tiny train+export is shared by every engine-based test
//! (`OnceLock`); the timing-sensitive queue tests use a deliberately
//! slow test double instead of the real engine, so their saturation and
//! drain scenarios are deterministic.

mod common;

use std::sync::{Arc, OnceLock};
use std::time::Duration;

use common::art_dir;
use geta::deploy::{GetaContainer, GetaEngine, KernelKind};
use geta::runtime::HostArray;
use geta::serve::{loadgen, BatchModel, ModelCache, ServeConfig, ServeError, Server};

struct Setup {
    container: GetaContainer,
    /// Single-sample requests (the serving unit of work).
    singles: Vec<HostArray>,
    /// One request spanning several micro-batches (32/32/6 for mlp_tiny).
    multi: HostArray,
}

fn setup() -> &'static Setup {
    static CELL: OnceLock<Setup> = OnceLock::new();
    CELL.get_or_init(|| {
        let art = geta::report::train_export(&art_dir(), "mlp_tiny", 0.1, 0.5, 8.0)
            .expect("mlp_tiny trains natively");
        let eval = &art.trainer.eval_data;
        let singles = loadgen::single_sample_inputs(eval, 12);
        let idxs: Vec<usize> = (0..70).map(|i| i % eval.len()).collect();
        let (multi, _) = eval.batch(&idxs);
        Setup {
            container: art.container,
            singles,
            multi,
        }
    })
}

fn engine(threads: usize) -> Arc<GetaEngine> {
    let mut e = GetaEngine::from_container_kernel(&setup().container, KernelKind::Int8)
        .expect("container round-trips");
    e.threads = threads;
    Arc::new(e)
}

// ---------------------------------------------------------------- 2
#[test]
fn concurrent_infer_matches_sequential_bitwise() {
    let s = setup();
    let e = engine(1);
    let seq: Vec<Vec<f32>> = s.singles.iter().map(|x| e.infer(x).unwrap()).collect();
    let seq_multi = e.infer(&s.multi).unwrap();
    // four threads hammering one shared engine, interleaved arbitrarily
    std::thread::scope(|sc| {
        for _ in 0..4 {
            sc.spawn(|| {
                for _round in 0..3 {
                    for (x, want) in s.singles.iter().zip(&seq) {
                        assert_eq!(&e.infer(x).unwrap(), want, "concurrent infer drifted");
                    }
                    assert_eq!(e.infer(&s.multi).unwrap(), seq_multi);
                }
            });
        }
    });
    // the chunk-sharding path (threads > 1) is bitwise identical too
    let sharded = engine(4);
    assert_eq!(sharded.infer(&s.multi).unwrap(), seq_multi);
    // and infer_many with mixed request sizes preserves per-request results
    let outs = e
        .infer_many(&[&s.singles[0], &s.multi, &s.singles[1]])
        .unwrap();
    assert_eq!(outs.len(), 3);
    assert_eq!(outs[0], seq[0]);
    assert_eq!(outs[1], seq_multi);
    assert_eq!(outs[2], seq[1]);
}

// ---------------------------------------------------------------- 1
#[test]
fn coalesced_serving_is_bitwise_identical_at_every_config() {
    let s = setup();
    let e = engine(1);
    let mut requests: Vec<HostArray> = s.singles.clone();
    requests.push(s.multi.clone());
    let direct: Vec<Vec<f32>> = requests.iter().map(|x| e.infer(x).unwrap()).collect();
    for workers in [1usize, 2, 4] {
        for window_us in [0u64, 2000] {
            for max_batch in [1usize, 4] {
                let server = Server::start(
                    e.clone(),
                    ServeConfig {
                        workers,
                        queue_depth: 64,
                        batch_window: Duration::from_micros(window_us),
                        max_batch,
                    },
                );
                let tickets: Vec<_> = requests
                    .iter()
                    .map(|x| server.submit(x.clone()).expect("queue has room"))
                    .collect();
                for (t, want) in tickets.into_iter().zip(&direct) {
                    let reply = t.wait().expect("request served");
                    assert_eq!(
                        &reply.logits, want,
                        "served logits drifted at workers={workers} window_us={window_us} \
                         max_batch={max_batch}"
                    );
                }
                let report = server.shutdown();
                assert_eq!(report.stats.accepted, requests.len() as u64);
                assert_eq!(report.stats.completed, requests.len() as u64);
                assert_eq!(report.stats.shed, 0);
                assert_eq!(report.histogram.count(), requests.len() as u64);
            }
        }
    }
}

/// Deliberately slow model: makes saturation and drain scenarios
/// deterministic instead of racing a fast real engine.
struct SleepyModel {
    delay: Duration,
}

impl BatchModel for SleepyModel {
    fn infer_many(&self, xs: &[&HostArray]) -> anyhow::Result<Vec<Vec<f32>>> {
        std::thread::sleep(self.delay);
        Ok(xs.iter().map(|x| vec![x.len() as f32]).collect())
    }
}

struct FailingModel;

impl BatchModel for FailingModel {
    fn infer_many(&self, _xs: &[&HostArray]) -> anyhow::Result<Vec<Vec<f32>>> {
        anyhow::bail!("synthetic model failure")
    }
}

fn tiny_request() -> HostArray {
    HostArray::F32(vec![1.0, 2.0])
}

// ---------------------------------------------------------------- 3
#[test]
fn saturated_queue_sheds_typed_error_and_server_stays_live() {
    let server = Server::start(
        Arc::new(SleepyModel {
            delay: Duration::from_millis(40),
        }),
        ServeConfig {
            workers: 1,
            queue_depth: 2,
            batch_window: Duration::ZERO,
            max_batch: 1,
        },
    );
    // a 40ms-per-request worker can't keep up with a tight submit loop:
    // the depth-2 queue must reject (typed, immediate — never block)
    let mut tickets = Vec::new();
    let mut shed = false;
    while !shed {
        match server.submit(tiny_request()) {
            Ok(t) => tickets.push(t),
            Err(e) => {
                assert_eq!(e, ServeError::QueueFull { depth: 2 });
                shed = true;
            }
        }
        assert!(tickets.len() < 100, "queue never saturated");
    }
    // every accepted request still completes: the shed cost the shed
    // request only, not the server
    for t in tickets {
        t.wait().expect("accepted request must complete");
    }
    // and the server keeps accepting new work
    let t = server.submit(tiny_request()).expect("server live after shed");
    t.wait().expect("post-shed request served");
    let report = server.shutdown();
    assert!(report.stats.shed >= 1, "shed counter must record the rejection");
}

// ---------------------------------------------------------------- 4
#[test]
fn shutdown_drains_every_accepted_request() {
    let server = Server::start(
        Arc::new(SleepyModel {
            delay: Duration::from_millis(5),
        }),
        ServeConfig {
            workers: 2,
            queue_depth: 64,
            batch_window: Duration::from_micros(200),
            max_batch: 4,
        },
    );
    let n = 32usize;
    let tickets: Vec<_> = (0..n)
        .map(|_| server.submit(tiny_request()).expect("queue has room"))
        .collect();
    assert_eq!(server.stats().accepted, n as u64);
    // shutdown must block until the queue is drained — not drop the tail
    let report = server.shutdown();
    assert_eq!(report.stats.completed, n as u64, "drain lost requests");
    assert_eq!(report.histogram.count(), n as u64);
    for t in tickets {
        t.wait().expect("accepted request resolved after shutdown");
    }
    // post-shutdown coalescing actually happened (2 workers, window > 0):
    // strictly fewer batches than requests
    assert!(
        report.stats.batches < n as u64,
        "expected some coalescing: {} batches for {n} requests",
        report.stats.batches
    );
}

#[test]
fn model_errors_fail_requests_not_the_server() {
    let server = Server::start(
        Arc::new(FailingModel),
        ServeConfig {
            workers: 1,
            queue_depth: 8,
            batch_window: Duration::ZERO,
            max_batch: 2,
        },
    );
    let err = server
        .submit(tiny_request())
        .expect("admission works")
        .wait()
        .expect_err("model failure must surface to the caller");
    assert!(err.to_string().contains("synthetic model failure"), "{err:#}");
    // the worker survived the failed batch
    let err2 = server.submit(tiny_request()).unwrap().wait().unwrap_err();
    assert!(err2.to_string().contains("synthetic model failure"));
    let report = server.shutdown();
    assert_eq!(report.stats.completed, 2);
    // failed requests record no latency: the histogram holds successes only
    assert_eq!(report.histogram.count(), 0);
}

// ---------------------------------------------------------------- 5
#[test]
fn model_cache_loads_once_and_pins_serving_threads() {
    let s = setup();
    let path = std::env::temp_dir().join("geta_test_serve_cache.geta");
    std::fs::write(&path, s.container.to_bytes()).expect("write artifact");
    let cache = ModelCache::new(KernelKind::Int8);
    assert!(cache.is_empty());
    let a = cache.get_or_load(&path).expect("artifact loads");
    let b = cache.get_or_load(&path).expect("cache hit");
    assert!(Arc::ptr_eq(&a, &b), "second lookup must share, not reload");
    assert_eq!(cache.len(), 1);
    assert_eq!(a.threads, 1, "cached engines serve with kernel threads pinned");
    // the cached engine is the same model: bitwise-equal logits
    let direct = engine(1);
    assert_eq!(
        a.infer(&s.singles[0]).unwrap(),
        direct.infer(&s.singles[0]).unwrap()
    );
    std::fs::remove_file(&path).ok();
}

/// End-to-end through the load generator: pressure mode admits every
/// request eventually, open-loop never blocks, and the served histogram
/// counts exactly the completions.
#[test]
fn load_generator_accounting_is_consistent() {
    let server = Server::start(
        Arc::new(SleepyModel {
            delay: Duration::from_millis(2),
        }),
        ServeConfig {
            workers: 2,
            queue_depth: 4,
            batch_window: Duration::from_micros(100),
            max_batch: 4,
        },
    );
    let inputs = vec![tiny_request()];
    let load = loadgen::run(
        &server,
        &inputs,
        &loadgen::LoadSpec {
            rps: 0.0, // pressure mode: every request is eventually admitted
            requests: 40,
            clients: 2,
            ..Default::default()
        },
    );
    assert_eq!(load.submitted, 40);
    assert!(
        load.attempts >= load.submitted,
        "attempts counts every submit call, including shed retries"
    );
    assert_eq!(load.completed, 40, "pressure mode loses no requests");
    assert_eq!(load.failed, 0);
    let report = server.shutdown();
    assert_eq!(report.stats.completed, 40);
    assert_eq!(report.histogram.count(), 40);
    assert_eq!(report.stats.accepted, 40);
    assert!(load.achieved_rps > 0.0);
}
