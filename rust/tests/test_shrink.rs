//! Shrink-as-you-train contract tests.
//!
//! The re-planner's whole promise is **bitwise identity**: physically
//! slicing the pruned channels out of the live parameters and rebuilding
//! the executor Plan on the shrunken subnet must not move a single bit of
//! the training trajectory relative to the masked-dense loop. These tests
//! state that promise directly — per-step losses, post-training eval
//! logits and every surviving parameter value are compared with
//! `f32::to_bits`, never with tolerances — and add the same guarantee for
//! `.getackpt` halt/resume: a run interrupted at an arbitrary step and
//! resumed must replay into the exact same bit pattern as one that never
//! stopped.

mod common;

use common::art_dir;
use geta::config::ExperimentConfig;
use geta::coordinator::{Compressor as _, GetaCompressor, TrainOpts, Trained, Trainer};
use geta::graph;
use geta::optim::qasso::StageMask;
use geta::runtime::Backend as _;
use geta::subnet::KeptMap;

fn small_exp(model: &str, sparsity: f64, scale: f64) -> ExperimentConfig {
    let mut e = ExperimentConfig::defaults_for(model);
    e.scale_steps(scale);
    e.n_train = 256;
    e.n_eval = 128;
    e.qasso.target_group_sparsity = sparsity;
    e
}

/// Run one GETA training pass and return (trained, final pruned mask,
/// logits of the first eval batch through the trainer's own engine on the
/// dense-coordinate params).
fn run(exp: ExperimentConfig, opts: &TrainOpts) -> (Trained, Vec<bool>, Vec<u32>) {
    let t = Trainer::new(&art_dir(), exp).expect("backend builds for every lowered family");
    let mut g = GetaCompressor::new(&*t.engine, &t.exp, StageMask::default()).unwrap();
    let trained = t.run_trained_opts(&mut g, opts).unwrap();
    let pruned = g.pruned_mask().expect("GETA exposes a pruned mask").to_vec();
    let idxs: Vec<usize> = (0..t.batch_size().min(t.eval_data.len())).collect();
    let (x, y) = t.eval_data.batch(&idxs);
    let logits = t
        .engine
        .eval_logits(&trained.params, &trained.q, &x, &y)
        .unwrap()
        .iter()
        .map(|v| v.to_bits())
        .collect();
    (trained, pruned, logits)
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// Dense-masked vs shrink-enabled training on `model`: same losses, same
/// logits, same surviving parameters — bitwise — and the shrink run must
/// actually have re-planned (otherwise this test proves nothing).
fn assert_shrink_matches_dense(model: &str, sparsity: f64, scale: f64) {
    let (dense, dense_mask, dense_logits) = run(small_exp(model, sparsity, scale), &TrainOpts::default());
    let (shrink, shrink_mask, shrink_logits) = run(
        small_exp(model, sparsity, scale),
        &TrainOpts {
            replan: true,
            ..Default::default()
        },
    );
    assert!(
        !shrink.replans.is_empty(),
        "{model}: the schedule pruned nothing — no re-plan ever happened, so the \
         shrink-vs-dense comparison is vacuous (raise sparsity or steps)"
    );
    assert_eq!(dense_mask, shrink_mask, "{model}: final pruned masks diverged");
    assert_eq!(
        dense.losses.len(),
        shrink.losses.len(),
        "{model}: step counts diverged"
    );
    for (i, (d, s)) in dense.losses.iter().zip(&shrink.losses).enumerate() {
        assert_eq!(
            d.to_bits(),
            s.to_bits(),
            "{model}: loss diverged at step {i} (first re-plan after step {:?}): dense {d:?} vs shrink {s:?}",
            shrink.replans.first()
        );
    }
    assert_eq!(dense_logits, shrink_logits, "{model}: eval logits diverged");
    // every SURVIVING parameter bitwise equal. Pruned positions are
    // excluded on both sides: the shrink run zero-expands them, while the
    // dense run lets weight decay shave the in-axis rows that multiply
    // zero activations — dead weight with no forward effect (the loss and
    // logit identity above is the proof).
    let cfg = &Trainer::new(&art_dir(), small_exp(model, sparsity, scale))
        .unwrap()
        .engine
        .manifest()
        .config
        .clone();
    let space = graph::search_space_for(cfg).unwrap();
    let kept = KeptMap::from_groups(&space.groups, &dense_mask);
    for dt in &dense.params.tensors {
        let st = shrink.params.get(&dt.name).expect("same tensor set");
        assert_eq!(
            bits(&kept.slice(dt).data),
            bits(&kept.slice(st).data),
            "{model}: surviving values of `{}` diverged",
            dt.name
        );
    }
    for (i, (dq, sq)) in dense.q.iter().zip(&shrink.q).enumerate() {
        assert_eq!(
            (dq.d.to_bits(), dq.t.to_bits(), dq.qm.to_bits()),
            (sq.d.to_bits(), sq.t.to_bits(), sq.qm.to_bits()),
            "{model}: quantizer site {i} diverged"
        );
    }
}

#[test]
fn shrink_is_bitwise_identical_to_dense_on_mlp() {
    assert_shrink_matches_dense("mlp_tiny", 0.85, 0.12);
}

/// The conv + batch-norm path is where bit-exactness is most at risk
/// (im2col GEMM reductions, per-channel norm statistics): prove the
/// identity on a real CNN, not just the MLP.
#[test]
fn shrink_is_bitwise_identical_to_dense_on_resnet() {
    assert_shrink_matches_dense("resnet_mini", 0.8, 0.1);
}

/// Halt a shrink-enabled run mid-schedule (after its first re-plan, so
/// the checkpoint carries a non-trivial slice map), resume it from the
/// `.getackpt`, and demand the stitched run be bitwise identical to one
/// that never stopped — losses, logits, surviving params, quantizers.
#[test]
fn halt_resume_is_bitwise_identical_to_uninterrupted() {
    let model = "mlp_tiny";
    let exp = || small_exp(model, 0.85, 0.12);
    let replan = TrainOpts {
        replan: true,
        ..Default::default()
    };
    let (full, full_mask, full_logits) = run(exp(), &replan);
    assert!(!full.replans.is_empty(), "schedule never pruned; pick a longer run");
    // halt two steps after the first re-plan: the checkpoint then holds
    // sliced params + optimizer stores and a non-empty kept map
    let halt = (full.replans[0] + 2).min(full.losses.len() - 1);
    let ckpt = std::env::temp_dir().join(format!(
        "geta_test_shrink_resume_{}.getackpt",
        std::process::id()
    ));
    let (halted, _, _) = run(
        exp(),
        &TrainOpts {
            replan: true,
            ckpt: Some(ckpt.clone()),
            halt_at: Some(halt),
            ..Default::default()
        },
    );
    assert!(halted.halted, "run must report the halt");
    assert_eq!(halted.losses.len(), halt, "halted at the wrong step");
    let (resumed, resumed_mask, resumed_logits) = run(
        exp(),
        &TrainOpts {
            replan: true,
            resume: Some(ckpt.clone()),
            ..Default::default()
        },
    );
    std::fs::remove_file(&ckpt).ok();
    assert!(!resumed.halted);
    assert_eq!(full_mask, resumed_mask, "final pruned masks diverged across resume");
    assert_eq!(bits(&full.losses), bits(&resumed.losses), "loss curves diverged across resume");
    assert_eq!(full_logits, resumed_logits, "eval logits diverged across resume");
    assert_eq!(
        full.replans, resumed.replans,
        "re-plan history diverged across resume"
    );
    for ft in &full.params.tensors {
        let rt = resumed.params.get(&ft.name).expect("same tensor set");
        assert_eq!(
            bits(&ft.data),
            bits(&rt.data),
            "trained values of `{}` diverged across resume",
            ft.name
        );
    }
    for (i, (fq, rq)) in full.q.iter().zip(&resumed.q).enumerate() {
        assert_eq!(
            (fq.d.to_bits(), fq.t.to_bits(), fq.qm.to_bits()),
            (rq.d.to_bits(), rq.t.to_bits(), rq.qm.to_bits()),
            "quantizer site {i} diverged across resume"
        );
    }
}

/// Same halt/resume identity for the plain masked-dense loop (no
/// re-planning): the checkpoint's kept map is empty and the resume path
/// must NOT build a shrunken engine.
#[test]
fn dense_halt_resume_is_bitwise_identical() {
    let model = "mlp_tiny";
    let exp = || small_exp(model, 0.5, 0.12);
    let (full, _, full_logits) = run(exp(), &TrainOpts::default());
    let halt = full.losses.len() / 3;
    let ckpt = std::env::temp_dir().join(format!(
        "geta_test_dense_resume_{}.getackpt",
        std::process::id()
    ));
    let (halted, _, _) = run(
        exp(),
        &TrainOpts {
            ckpt: Some(ckpt.clone()),
            halt_at: Some(halt),
            ..Default::default()
        },
    );
    assert!(halted.halted);
    let (resumed, _, resumed_logits) = run(
        exp(),
        &TrainOpts {
            resume: Some(ckpt.clone()),
            ..Default::default()
        },
    );
    std::fs::remove_file(&ckpt).ok();
    assert_eq!(bits(&full.losses), bits(&resumed.losses), "loss curves diverged across resume");
    assert_eq!(full_logits, resumed_logits, "eval logits diverged across resume");
    for ft in &full.params.tensors {
        let rt = resumed.params.get(&ft.name).expect("same tensor set");
        assert_eq!(
            bits(&ft.data),
            bits(&rt.data),
            "trained values of `{}` diverged across resume",
            ft.name
        );
    }
}

/// A `.getackpt` damaged on disk — truncated or bit-flipped at any
/// 64-byte window — must fail `--resume` with a typed error, never a
/// panic: these are exactly the bytes a crash-interrupted run reads back
/// (`util::atomic_write` makes torn files unreachable in practice; this
/// sweep covers damage from any other source).
#[test]
fn corrupt_checkpoints_fail_typed_never_panic() {
    let ckpt = std::env::temp_dir().join(format!(
        "geta_test_ckpt_corrupt_{}.getackpt",
        std::process::id()
    ));
    let (halted, _, _) = run(
        small_exp("mlp_tiny", 0.85, 0.12),
        &TrainOpts {
            replan: true,
            ckpt: Some(ckpt.clone()),
            halt_at: Some(4),
            ..Default::default()
        },
    );
    assert!(halted.halted);
    let bytes = std::fs::read(&ckpt).unwrap();
    std::fs::remove_file(&ckpt).ok();
    common::assert_corruption_safe(".getackpt", &bytes, &|b| {
        geta::coordinator::ckpt::TrainCkpt::from_bytes(b).is_ok()
    });
}

/// Periodic checkpointing must not perturb the run: `--ckpt-every` writes
/// are pure observers of training state.
#[test]
fn periodic_checkpoints_do_not_perturb_training() {
    let model = "mlp_tiny";
    let exp = || small_exp(model, 0.85, 0.1);
    let (plain, _, plain_logits) = run(
        exp(),
        &TrainOpts {
            replan: true,
            ..Default::default()
        },
    );
    let ckpt = std::env::temp_dir().join(format!(
        "geta_test_periodic_{}.getackpt",
        std::process::id()
    ));
    let (ckpted, _, ckpted_logits) = run(
        exp(),
        &TrainOpts {
            replan: true,
            ckpt: Some(ckpt.clone()),
            ckpt_every: 10,
            ..Default::default()
        },
    );
    // the final periodic checkpoint must itself load cleanly
    let loaded = geta::coordinator::ckpt::TrainCkpt::load(&ckpt).unwrap();
    assert_eq!(loaded.model, model);
    std::fs::remove_file(&ckpt).ok();
    assert_eq!(bits(&plain.losses), bits(&ckpted.losses));
    assert_eq!(plain_logits, ckpted_logits);
}
