//! Offline stand-in for the `anyhow` crate.
//!
//! The build environment is fully offline (see rust/src/util/mod.rs), so
//! this vendored path crate provides the exact API subset geta uses:
//! `Error`, `Result<T>`, the `anyhow!` / `bail!` / `ensure!` macros, and
//! the `Context` extension trait over `Result` and `Option`. Error values
//! carry a message plus an optional source chain and render identically
//! to anyhow's single-line `{context}: {cause}` Display format.

use std::error::Error as StdError;
use std::fmt;

/// Boxed dynamic error with prepended context messages.
pub struct Error {
    msg: String,
    source: Option<Box<dyn StdError + Send + Sync + 'static>>,
}

impl Error {
    /// Build an error from a plain message (what `anyhow!` expands to).
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error {
            msg: message.to_string(),
            source: None,
        }
    }

    /// Wrap a concrete `std::error::Error`.
    pub fn new<E: StdError + Send + Sync + 'static>(error: E) -> Error {
        Error {
            msg: error.to_string(),
            source: Some(Box::new(error)),
        }
    }

    /// Prepend a context message (`{context}: {self}`).
    pub fn context<C: fmt::Display>(self, context: C) -> Error {
        Error {
            msg: format!("{context}: {}", self.msg),
            source: self.source,
        }
    }

    /// The root cause, when this error wraps a concrete one.
    pub fn source(&self) -> Option<&(dyn StdError + 'static)> {
        self.source.as_deref().map(|e| e as &(dyn StdError + 'static))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // anyhow renders Debug as the Display chain; `fn main() -> Result<()>`
        // exits through this path, so keep it readable.
        f.write_str(&self.msg)
    }
}

// NOTE: `Error` intentionally does NOT implement `std::error::Error`; that
// is what makes this blanket conversion coherent (same trick as anyhow).
impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(error: E) -> Error {
        Error::new(error)
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Context-attaching extension for fallible values.
pub trait Context<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T>;
    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: StdError + Send + Sync + 'static> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::new(e).context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| Error::new(e).context(f()))
    }
}

impl<T> Context<T> for std::result::Result<T, Error> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T> {
        self.map_err(|e| e.context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// `anyhow!("fmt {args}")` — construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(format!("{}", $err))
    };
}

/// `bail!(...)` — early-return `Err(anyhow!(...))`.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// `ensure!(cond, ...)` — bail with the message when `cond` is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::anyhow!(concat!("condition failed: ", stringify!($cond))));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert_eq!(inner().unwrap_err().to_string(), "gone");
    }

    #[test]
    fn context_chains_messages() {
        let e: Result<()> = Err(io_err()).context("reading manifest");
        assert_eq!(e.unwrap_err().to_string(), "reading manifest: gone");
        let e: Result<()> = Err(anyhow!("base")).with_context(|| format!("step {}", 2));
        assert_eq!(e.unwrap_err().to_string(), "step 2: base");
        let v: Result<i32> = None.context("missing key");
        assert_eq!(v.unwrap_err().to_string(), "missing key");
    }

    #[test]
    fn macros_format_and_bail() {
        fn f(x: i32) -> Result<i32> {
            ensure!(x >= 0, "negative input {x}");
            if x == 0 {
                bail!("zero not allowed");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(f(0).unwrap_err().to_string(), "zero not allowed");
        assert_eq!(f(-2).unwrap_err().to_string(), "negative input -2");
        assert_eq!(anyhow!("n={}", 5).to_string(), "n=5");
    }

    #[test]
    fn source_is_preserved() {
        let e = Error::new(io_err()).context("outer");
        assert!(e.source().is_some());
        assert_eq!(e.to_string(), "outer: gone");
    }
}
