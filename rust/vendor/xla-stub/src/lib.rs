//! Stub of the `xla` crate's API surface used by `rust/src/runtime/pjrt.rs`.
//!
//! Keeps `cargo build --features pjrt` compiling on machines without the
//! XLA/PJRT toolchain: every entry point type-checks, and the first runtime
//! call (`PjRtClient::cpu()`) returns an error directing the user to swap
//! in the real bindings. To run against real artifacts, point the `xla`
//! path dependency in Cargo.toml at an actual `xla` crate checkout (the
//! image used for `make artifacts` ships one under /opt/xla-example).

use std::fmt;

#[derive(Debug)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla stub: {}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(Error(format!(
        "{what} is unavailable — this build links the vendored xla stub; \
         point the `xla` path dependency at real xla bindings to use PJRT"
    )))
}

/// Element types the runtime moves across the PJRT boundary.
pub trait NativeType: Copy + Default {}
impl NativeType for f32 {}
impl NativeType for f64 {}
impl NativeType for i32 {}
impl NativeType for i64 {}

/// Host-side literal (opaque in the stub; never holds data).
pub struct Literal;

impl Literal {
    pub fn vec1<T: NativeType>(_data: &[T]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Ok(Literal)
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        unavailable("Literal::to_vec")
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        unavailable("Literal::to_tuple")
    }
}

pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        unavailable("HloModuleProto::from_text_file")
    }
}

pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable("PjRtClient::cpu")
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }
}

pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute")
    }
}
