#!/usr/bin/env python3
"""Splice the measured tables (reports/*.md) into EXPERIMENTS.md at the
<!-- MEASURED:id --> markers. Run after `geta repro all`."""

import re
import sys
import os

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def main():
    exp_path = os.path.join(ROOT, "EXPERIMENTS.md")
    text = open(exp_path).read()

    def repl(m):
        key = m.group(1)
        path = os.path.join(ROOT, "reports", f"{key}.md")
        if not os.path.exists(path):
            return m.group(0)
        body = open(path).read().strip()
        return f"<!-- MEASURED:{key} -->\n\n{body}\n"

    out = re.sub(r"<!-- MEASURED:(\w+) -->\n?", repl, text)
    open(exp_path, "w").write(out)
    filled = len(re.findall(r"<!-- MEASURED:\w+ -->\n\n\|", out))
    print(f"filled {filled} measured sections")


if __name__ == "__main__":
    main()
