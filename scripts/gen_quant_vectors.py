"""Generate the checked-in small golden-vector set for the Rust quantizer.

Numpy float32 mirror of python/compile/kernels/ref.py (the pure-jnp oracle
for eqs. (1)-(6), (13)-(14)); jnp and numpy agree to float32 precision on
these elementwise formulas, so this script needs no JAX install. Output is
committed at rust/tests/data/quant_vectors_small.json and consumed by
rust/tests/test_quant_vectors.rs whenever `make artifacts` has not produced
the full artifacts/quant_vectors.json.

Usage: python3 scripts/gen_quant_vectors.py
"""

import json
import os

import numpy as np

EPS = np.float32(1e-12)


def clip_pow(x, t, qm):
    ax = np.abs(x)
    return np.where(ax <= qm, np.power(np.maximum(ax, EPS), t),
                    np.power(np.maximum(qm, EPS), t)).astype(np.float32)


def fake_quant(x, d, t, qm):
    xt = np.sign(x) * clip_pow(x, t, qm)
    return (d * np.round(xt / d)).astype(np.float32)


def residual(x, d, t, qm):
    c = clip_pow(x, t, qm)
    return (np.round(c / d) - c / d).astype(np.float32)


def bit_width(d, t, qm):
    return float(np.log2(np.power(np.maximum(qm, EPS), t) / d + np.float32(1.0)) + np.float32(1.0))


def grad_d(x, d, t, qm):
    return (np.sign(x) * residual(x, d, t, qm)).astype(np.float32)


def grad_t(x, d, t, qm):
    ax = np.abs(x)
    inside = np.power(np.maximum(ax, EPS), t) * np.log(np.maximum(ax, EPS))
    outside = np.power(np.maximum(qm, EPS), t) * np.log(np.maximum(qm, EPS))
    g = np.where(ax <= qm, inside, outside)
    return (np.sign(x) * np.where(ax <= EPS, np.float32(0.0), g)).astype(np.float32)


def grad_qm(x, d, t, qm):
    ax = np.abs(x)
    return np.where(ax <= qm, np.float32(0.0),
                    np.sign(x) * t * np.power(np.maximum(qm, EPS), t - np.float32(1.0))).astype(np.float32)


def main():
    rng = np.random.default_rng(42)
    cases = []
    for (d, t, qm) in [(0.1, 1.0, 1.0), (0.05, 1.2, 0.8), (0.02, 0.9, 2.0),
                       (0.25, 1.0, 0.5), (0.004, 1.05, 1.5)]:
        d32, t32, qm32 = np.float32(d), np.float32(t), np.float32(qm)
        x = np.concatenate([
            rng.normal(scale=0.7, size=24),
            np.array([0.0, qm, -qm, qm * 1.5, -qm * 2.0, d / 2, -d / 2]),
        ]).astype(np.float32)
        cases.append({
            "d": d, "t": t, "qm": qm,
            "x": [float(v) for v in x],
            "xq": [float(v) for v in fake_quant(x, d32, t32, qm32)],
            "clip": [float(v) for v in clip_pow(x, t32, qm32)],
            "residual": [float(v) for v in residual(x, d32, t32, qm32)],
            "grad_d": [float(v) for v in grad_d(x, d32, t32, qm32)],
            "grad_t": [float(v) for v in grad_t(x, d32, t32, qm32)],
            "grad_qm": [float(v) for v in grad_qm(x, d32, t32, qm32)],
            "bit_width": bit_width(d32, t32, qm32),
        })
    out = os.path.join(os.path.dirname(__file__), "..", "rust", "tests", "data",
                       "quant_vectors_small.json")
    with open(out, "w") as f:
        json.dump({"cases": cases}, f)
    print(f"wrote {len(cases)} vector cases to {os.path.normpath(out)}")


if __name__ == "__main__":
    main()
