"""Generate the checked-in small golden-vector sets for the Rust backend.

Two files, both numpy mirrors of the pure-jnp oracles (jnp and numpy agree
to float32 precision on these formulas, so this script needs no JAX
install):

* rust/tests/data/quant_vectors_small.json — the quantizer math of
  python/compile/kernels/ref.py (eqs. (1)-(6), (13)-(14)), consumed by
  rust/tests/test_quant_vectors.rs whenever `make artifacts` has not
  produced the full artifacts/quant_vectors.json.
* rust/tests/data/op_vectors_small.json — forward AND backward vectors for
  the native interpreter's structural ops (conv2d on the im2col path with
  XLA SAME/VALID padding, layernorm, softmax, multi-head attention
  QK^T/softmax/V incl. the causal mask, tanh-gelu), mirroring
  python/compile/models/common.py. Gradients are analytic (finite-
  difference-validated) and computed in float64 over float32 inputs, the
  same accumulation discipline as rust/src/tensor/ops.rs, so the Rust side
  matches at 1e-5.
* rust/tests/data/u4_vectors_small.json — nibble-packed 4-bit GEMM
  vectors for rust/src/tensor/u4.rs: weight levels in [-7, 7], the
  LSB-first packed bytes (low nibble = even column — checked byte-for-byte
  on the Rust side, pinning the cross-language panel layout), i8 and f32
  activations, per-channel scales/bias, and f64-computed reference outputs
  for the raw i32 GEMM (exact) and both scaled epilogues (1e-5).

Usage: python3 scripts/gen_quant_vectors.py
"""

import json
import os

import numpy as np

EPS = np.float32(1e-12)


def clip_pow(x, t, qm):
    ax = np.abs(x)
    return np.where(ax <= qm, np.power(np.maximum(ax, EPS), t),
                    np.power(np.maximum(qm, EPS), t)).astype(np.float32)


def fake_quant(x, d, t, qm):
    xt = np.sign(x) * clip_pow(x, t, qm)
    return (d * np.round(xt / d)).astype(np.float32)


def residual(x, d, t, qm):
    c = clip_pow(x, t, qm)
    return (np.round(c / d) - c / d).astype(np.float32)


def bit_width(d, t, qm):
    return float(np.log2(np.power(np.maximum(qm, EPS), t) / d + np.float32(1.0)) + np.float32(1.0))


def grad_d(x, d, t, qm):
    return (np.sign(x) * residual(x, d, t, qm)).astype(np.float32)


def grad_t(x, d, t, qm):
    ax = np.abs(x)
    inside = np.power(np.maximum(ax, EPS), t) * np.log(np.maximum(ax, EPS))
    outside = np.power(np.maximum(qm, EPS), t) * np.log(np.maximum(qm, EPS))
    g = np.where(ax <= qm, inside, outside)
    return (np.sign(x) * np.where(ax <= EPS, np.float32(0.0), g)).astype(np.float32)


def grad_qm(x, d, t, qm):
    ax = np.abs(x)
    return np.where(ax <= qm, np.float32(0.0),
                    np.sign(x) * t * np.power(np.maximum(qm, EPS), t - np.float32(1.0))).astype(np.float32)


# ------------------------------------------------------- interpreter ops
#
# Float64 compute over float32 inputs (the Rust kernels' accumulation
# discipline). conv2d mirrors rust/src/tensor/ops.rs: NHWC x, HWIO w,
# im2col columns ordered (kh*k + kw)*c + ci, XLA SAME/VALID padding.


def conv_out_dim(h, k, stride, same):
    if same:
        out = -(-h // stride)
        total = max((out - 1) * stride + k - h, 0)
        return out, total // 2
    return (h - k) // stride + 1, 0


def im2col(x, k, stride, pad, ho, wo):
    b, h, w, c = x.shape
    cols = np.zeros((b * ho * wo, k * k * c), np.float64)
    for bi in range(b):
        for oh in range(ho):
            for ow in range(wo):
                r = (bi * ho + oh) * wo + ow
                for kh in range(k):
                    ih = oh * stride + kh - pad
                    if ih < 0 or ih >= h:
                        continue
                    for kw in range(k):
                        iw = ow * stride + kw - pad
                        if iw < 0 or iw >= w:
                            continue
                        base = (kh * k + kw) * c
                        cols[r, base:base + c] = x[bi, ih, iw, :]
    return cols


def col2im(gcols, xshape, k, stride, pad, ho, wo):
    b, h, w, c = xshape
    gx = np.zeros(xshape, np.float64)
    for bi in range(b):
        for oh in range(ho):
            for ow in range(wo):
                r = (bi * ho + oh) * wo + ow
                for kh in range(k):
                    ih = oh * stride + kh - pad
                    if ih < 0 or ih >= h:
                        continue
                    for kw in range(k):
                        iw = ow * stride + kw - pad
                        if iw < 0 or iw >= w:
                            continue
                        base = (kh * k + kw) * c
                        gx[bi, ih, iw, :] += gcols[r, base:base + c]
    return gx


def conv_case(rng, bshape, cout, k, stride, same):
    b, h, w, cin = bshape
    x = rng.normal(size=bshape).astype(np.float32).astype(np.float64)
    wt = rng.normal(scale=0.5, size=(k, k, cin, cout)).astype(np.float32).astype(np.float64)
    bias = rng.normal(size=cout).astype(np.float32).astype(np.float64)
    ho, pad = conv_out_dim(h, k, stride, same)
    wo, _ = conv_out_dim(w, k, stride, same)
    cols = im2col(x, k, stride, pad, ho, wo)
    wm = wt.reshape(k * k * cin, cout)
    y = cols @ wm + bias
    cot = rng.normal(size=y.shape).astype(np.float32).astype(np.float64)
    gw = cols.T @ cot
    gb = cot.sum(0)
    gx = col2im(cot @ wm.T, bshape, k, stride, pad, ho, wo)
    def f(a):
        return [float(np.float32(v)) for v in np.asarray(a).reshape(-1)]
    return {
        "kind": "conv2d", "b": b, "h": h, "w": w, "cin": cin, "cout": cout,
        "k": k, "stride": stride, "same": same,
        "x": f(x), "weight": f(wt), "bias": f(bias),
        "y": f(y), "cot": f(cot), "gx": f(gx), "gw": f(gw), "gb": f(gb),
    }


def layernorm_case(rng, rows, c, eps=1e-5):
    x = rng.normal(size=(rows, c)).astype(np.float32).astype(np.float64)
    gamma = (1.0 + 0.3 * rng.normal(size=c)).astype(np.float32).astype(np.float64)
    beta = (0.2 * rng.normal(size=c)).astype(np.float32).astype(np.float64)
    mu = x.mean(-1, keepdims=True)
    var = x.var(-1, keepdims=True)
    inv = 1.0 / np.sqrt(var + eps)
    xhat = (x - mu) * inv
    y = xhat * gamma + beta
    cot = rng.normal(size=y.shape).astype(np.float32).astype(np.float64)
    ggamma = (cot * xhat).sum(0)
    gbeta = cot.sum(0)
    dxhat = cot * gamma
    gx = inv / c * (c * dxhat - dxhat.sum(-1, keepdims=True)
                    - xhat * (dxhat * xhat).sum(-1, keepdims=True))
    def f(a):
        return [float(np.float32(v)) for v in np.asarray(a).reshape(-1)]
    return {
        "kind": "layernorm", "rows": rows, "c": c,
        "x": f(x), "gamma": f(gamma), "beta": f(beta),
        "y": f(y), "cot": f(cot),
        "gx": f(gx), "ggamma": f(ggamma), "gbeta": f(gbeta),
    }


def softmax_case(rng, rows, n):
    x = rng.normal(scale=2.0, size=(rows, n)).astype(np.float32).astype(np.float64)
    e = np.exp(x - x.max(-1, keepdims=True))
    p = e / e.sum(-1, keepdims=True)
    cot = rng.normal(size=p.shape).astype(np.float32).astype(np.float64)
    gx = p * (cot - (cot * p).sum(-1, keepdims=True))
    def f(a):
        return [float(np.float32(v)) for v in np.asarray(a).reshape(-1)]
    return {
        "kind": "softmax", "rows": rows, "n": n,
        "x": f(x), "p": f(p), "cot": f(cot), "gx": f(gx),
    }


def attention_case(rng, b, s, d, heads, causal):
    """Fused multi-head self-attention, forward + (dq, dk, dv) backward.

    Mirrors rust/src/runtime/interp.rs OpKind::Attention: per-head slices
    of width d/heads, QK^T scaled by 1/sqrt(head_dim), causal positions
    masked to -1e9 *after* scaling, softmax over keys, probs @ V.
    """
    hd = d // heads
    scale = 1.0 / np.sqrt(hd)
    q = rng.normal(size=(b, s, d)).astype(np.float32).astype(np.float64)
    k = rng.normal(size=(b, s, d)).astype(np.float32).astype(np.float64)
    v = rng.normal(size=(b, s, d)).astype(np.float32).astype(np.float64)
    cot = rng.normal(size=(b, s, d)).astype(np.float32).astype(np.float64)
    y = np.zeros((b, s, d), np.float64)
    gq = np.zeros((b, s, d), np.float64)
    gk = np.zeros((b, s, d), np.float64)
    gv = np.zeros((b, s, d), np.float64)
    for bi in range(b):
        for h in range(heads):
            sl = slice(h * hd, (h + 1) * hd)
            qh, kh, vh = q[bi, :, sl], k[bi, :, sl], v[bi, :, sl]
            att = qh @ kh.T * scale
            if causal:
                att = np.where(np.triu(np.ones((s, s), bool), 1), -1e9, att)
            e = np.exp(att - att.max(-1, keepdims=True))
            p = e / e.sum(-1, keepdims=True)
            y[bi, :, sl] = p @ vh
            dyh = cot[bi, :, sl]
            dp = dyh @ vh.T
            gv[bi, :, sl] = p.T @ dyh
            ds = p * (dp - (dp * p).sum(-1, keepdims=True)) * scale
            gq[bi, :, sl] = ds @ kh
            gk[bi, :, sl] = ds.T @ qh
    def f(a):
        return [float(np.float32(x)) for x in np.asarray(a).reshape(-1)]
    return {
        "kind": "attention", "b": b, "s": s, "d": d, "heads": heads,
        "causal": causal,
        "q": f(q), "k": f(k), "v": f(v), "y": f(y), "cot": f(cot),
        "gq": f(gq), "gk": f(gk), "gv": f(gv),
    }


def gelu_case(rng, n):
    """Tanh-approximated GELU (jax.nn.gelu default), forward + backward.

    Constants are the float32 values rust/src/tensor/ops.rs uses, so the
    only divergence left is f32-vs-f64 tanh rounding (< 1e-6 relative).
    """
    c = float(np.float32(0.7978846))
    kk = float(np.float32(0.044715))
    x = rng.normal(scale=1.5, size=n).astype(np.float32).astype(np.float64)
    u = c * (x + kk * x ** 3)
    t = np.tanh(u)
    y = 0.5 * x * (1.0 + t)
    cot = rng.normal(size=n).astype(np.float32).astype(np.float64)
    du = c * (1.0 + 3.0 * kk * x * x)
    gx = cot * (0.5 * (1.0 + t) + 0.5 * x * (1.0 - t * t) * du)
    def f(a):
        return [float(np.float32(v)) for v in np.asarray(a).reshape(-1)]
    return {"kind": "gelu", "n": n, "x": f(x), "y": f(y), "cot": f(cot), "gx": f(gx)}


# ------------------------------------------------------- u4 GEMM vectors
#
# Mirrors rust/src/tensor/u4.rs: [k, ceil(n/2)] row-major panels, two
# 4-bit two's-complement levels per byte, LSB-first (low nibble = even
# column, odd n leaves the last high nibble zero).


def pack_nibble_rows(levels, k, n):
    nb = (n + 1) // 2
    packed = []
    for r in range(k):
        row = levels[r * n:(r + 1) * n]
        for jb in range(nb):
            lo = int(row[2 * jb]) & 0x0F
            hi = (int(row[2 * jb + 1]) & 0x0F) if 2 * jb + 1 < n else 0
            packed.append(lo | (hi << 4))
    return packed


def u4_case(rng, m, k, n):
    levels = rng.integers(-7, 8, size=k * n)
    wm = levels.reshape(k, n).astype(np.int64)
    la = rng.integers(-127, 128, size=m * k)
    am = la.reshape(m, k).astype(np.int64)
    # raw i8 x u4 GEMM: exact i32 accumulation on both sides
    raw = am @ wm
    # scaled epilogue, replicating the Rust f64 discipline: acc * (f64(d_a)
    # * f64(scale_j)) + f64(bias_j), rounded once to f32
    alpha = np.float32(3e-3)
    scale = (np.float32(1e-3) + np.float32(1e-4) * np.arange(n, dtype=np.float32)).astype(np.float32)
    bias = (0.1 * rng.normal(size=n)).astype(np.float32)
    comb = np.float64(alpha) * scale.astype(np.float64)
    scaled = (raw.astype(np.float64) * comb + bias.astype(np.float64)).astype(np.float32)
    # mixed f32 x u4: f64 accumulation over f32 activations
    af = rng.normal(size=(m, k)).astype(np.float32)
    acc = af.astype(np.float64) @ wm.astype(np.float64)
    mixed = (acc * scale.astype(np.float64) + bias.astype(np.float64)).astype(np.float32)
    def f(a):
        return [float(np.float32(v)) for v in np.asarray(a).reshape(-1)]
    return {
        "m": m, "k": k, "n": n,
        "levels": [int(v) for v in levels],
        "packed": pack_nibble_rows(levels, k, n),
        "acts_i8": [int(v) for v in la],
        "acts_f32": f(af),
        "alpha": float(alpha),
        "scale": f(scale),
        "bias": f(bias),
        "raw": [int(v) for v in raw.reshape(-1)],
        "scaled": f(scaled),
        "mixed": f(mixed),
    }


def main():
    rng = np.random.default_rng(42)
    cases = []
    for (d, t, qm) in [(0.1, 1.0, 1.0), (0.05, 1.2, 0.8), (0.02, 0.9, 2.0),
                       (0.25, 1.0, 0.5), (0.004, 1.05, 1.5)]:
        d32, t32, qm32 = np.float32(d), np.float32(t), np.float32(qm)
        x = np.concatenate([
            rng.normal(scale=0.7, size=24),
            np.array([0.0, qm, -qm, qm * 1.5, -qm * 2.0, d / 2, -d / 2]),
        ]).astype(np.float32)
        cases.append({
            "d": d, "t": t, "qm": qm,
            "x": [float(v) for v in x],
            "xq": [float(v) for v in fake_quant(x, d32, t32, qm32)],
            "clip": [float(v) for v in clip_pow(x, t32, qm32)],
            "residual": [float(v) for v in residual(x, d32, t32, qm32)],
            "grad_d": [float(v) for v in grad_d(x, d32, t32, qm32)],
            "grad_t": [float(v) for v in grad_t(x, d32, t32, qm32)],
            "grad_qm": [float(v) for v in grad_qm(x, d32, t32, qm32)],
            "bit_width": bit_width(d32, t32, qm32),
        })
    data_dir = os.path.join(os.path.dirname(__file__), "..", "rust", "tests", "data")
    out = os.path.join(data_dir, "quant_vectors_small.json")
    with open(out, "w") as f:
        json.dump({"cases": cases}, f)
    print(f"wrote {len(cases)} vector cases to {os.path.normpath(out)}")

    op_rng = np.random.default_rng(7)
    op_cases = [
        # 3x3 SAME stride 1 (vgg/resnet body)
        conv_case(op_rng, (2, 5, 5, 3), 4, 3, 1, True),
        # 3x3 SAME stride 2 (resnet stage entry; asymmetric XLA padding)
        conv_case(op_rng, (1, 8, 8, 2), 3, 3, 2, True),
        # 1x1 SAME stride 2 (resnet projection)
        conv_case(op_rng, (1, 6, 6, 2), 4, 1, 2, True),
        # 4x4 VALID stride 4 (vit/swin patch embedding)
        conv_case(op_rng, (2, 8, 8, 3), 5, 4, 4, False),
        layernorm_case(op_rng, 4, 6),
        layernorm_case(op_rng, 7, 16),
        softmax_case(op_rng, 3, 7),
        softmax_case(op_rng, 5, 32),
        # multi-head attention (bert/vit block) + causal variant (gpt)
        attention_case(op_rng, 2, 4, 8, 2, False),
        attention_case(op_rng, 1, 6, 6, 3, True),
        # tanh-gelu (transformer mlp nonlinearity)
        gelu_case(op_rng, 37),
        gelu_case(op_rng, 64),
    ]
    out = os.path.join(data_dir, "op_vectors_small.json")
    with open(out, "w") as f:
        json.dump({"cases": op_cases}, f)
    print(f"wrote {len(op_cases)} op vector cases to {os.path.normpath(out)}")

    u4_rng = np.random.default_rng(1234)
    u4_cases = [
        u4_case(u4_rng, 3, 8, 5),    # odd n: tail nibble
        u4_case(u4_rng, 2, 1, 1),    # degenerate single element
        u4_case(u4_rng, 2, 7, 1),    # n=1: every byte is a lone low nibble
        u4_case(u4_rng, 4, 33, 16),  # even n, odd k
        u4_case(u4_rng, 5, 96, 11),  # k spans several accumulation tiles
    ]
    out = os.path.join(data_dir, "u4_vectors_small.json")
    with open(out, "w") as f:
        json.dump({"cases": u4_cases}, f)
    print(f"wrote {len(u4_cases)} u4 vector cases to {os.path.normpath(out)}")


if __name__ == "__main__":
    main()
